//! Batched structure-of-arrays (SoA) ACDC compute engine.
//!
//! The paper's §5 analysis shows the ACDC hot path is *memory-bound*: the
//! "single call" kernel wins because it touches each row once (8N bytes of
//! main-memory traffic per row — 4N in, 4N out; see DESIGN.md §4). The
//! scalar `DctPlan::dct2/dct3` path honours that traffic model but
//! transforms one row (or one packed pair) at a time, leaving batch-level
//! locality and SIMD on the table. This module is the batched counterpart,
//! the CPU analogue of cuFFT's batched transforms (DESIGN.md substitution
//! S3):
//!
//! * **Lane panels** — a `[rows, N]` batch is processed [`LANES`] rows at
//!   a time. Each panel is transposed into *structure-of-arrays* lanes:
//!   frequency bin `k` of all lanes lives contiguously at
//!   `buf[k*LANES .. (k+1)*LANES]`. Every inner loop of the transform then
//!   runs over the lane dimension with unit stride — one 256-bit vector
//!   register per lane block — and each twiddle load is amortized over
//!   [`LANES`] rows instead of one.
//! * **Real-FFT Makhoul path** — N real inputs are packed into an **N/2**
//!   complex FFT (`z[j] = v[2j] + i·v[2j+1]`, [`crate::dct::fft::RealFftPlan`])
//!   with an O(N) un-twist fused into the DCT twiddle stages, halving the
//!   butterfly count and the panel scratch traffic of the previous
//!   full-size complex path. The Makhoul even/odd reorder rides the
//!   pack/unpack transpose through the plan's source-index table.
//! * **Fused `A`/`D`/bias** — [`BatchEngine::acdc_rows`] executes a whole
//!   `ACDC⁻¹` layer (`y = ((x ⊙ a)·C ⊙ d + bias)·Cᵀ`): the `a` scale rides
//!   the input pack, and `d`/`bias` ride the single twist stage between
//!   the forward and inverse half-size FFTs. Intermediates never leave
//!   the panel scratch, so main memory sees exactly one load and one
//!   store per panel.
//! * **Runtime SIMD dispatch** — the FFT butterfly and twist stages run
//!   through [`crate::dct::simd`]: explicit AVX2 kernels behind a one-time
//!   `is_x86_feature_detected!` check, with the portable 8-wide loops as
//!   the mandatory (bit-identical) fallback; `ACDC_SIMD=scalar` forces it.
//! * **Panel parallelism** — [`BatchEngine::acdc_rows_parallel`] splits
//!   panels across the shared [`crate::util::threadpool`], the serving
//!   pool all SELL executors already use.
//!
//! Plans are cached process-wide in [`PlanCache`] so the gateway's serving
//! threads, the coordinator workers and every SELL variant share one
//! twiddle table per size.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::simd::{self, Dispatch, RealStage};
use super::DctPlan;
use crate::util::threadpool::{split_ranges, ThreadPool};

/// Rows per SoA panel. Eight f32 lanes fill one 256-bit vector register;
/// the panel scratch for N=8192 (2×N/2 + N lanes × 4 B) stays inside L2.
/// Exposed so callers (and the fastfood FWHT path) can size batches.
pub const LANES: usize = 8;

/// Below this many rows the scalar pair path (`DctPlan::dct2_pair`) wins:
/// a padded panel always computes all [`LANES`] lanes, so occupancy under
/// one half wastes more than the SoA layout saves.
pub const MIN_SOA_ROWS: usize = LANES / 2;

/// Process-wide `size → Arc<DctPlan>` cache.
///
/// Plan construction is O(N) trig plus an O(N²) lazily-built matrix;
/// serving threads, the batcher's executors and ad-hoc layer constructors
/// all want the same handful of power-of-two sizes. `get` hands out shared
/// handles so each size is built exactly once per process.
///
/// ```
/// use acdc::dct::PlanCache;
/// let a = PlanCache::get(64);
/// let b = PlanCache::get(64);
/// assert!(std::sync::Arc::ptr_eq(&a, &b)); // one plan per size, shared
/// ```
pub struct PlanCache;

static PLAN_CACHE: OnceLock<Mutex<HashMap<usize, Arc<DctPlan>>>> = OnceLock::new();

impl PlanCache {
    /// Shared plan for size `n` (built on first request). Panics if `n`
    /// is not a power of two, like [`DctPlan::new`].
    pub fn get(n: usize) -> Arc<DctPlan> {
        let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut guard = cache.lock().expect("plan cache poisoned");
        Arc::clone(guard.entry(n).or_insert_with(|| Arc::new(DctPlan::new(n))))
    }

    /// Sizes currently cached (ascending) — observability for tests and
    /// the `acdc info` diagnostics.
    pub fn cached_sizes() -> Vec<usize> {
        let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let guard = cache.lock().expect("plan cache poisoned");
        let mut sizes: Vec<usize> = guard.keys().copied().collect();
        sizes.sort_unstable();
        sizes
    }
}

/// Reusable per-panel scratch: two half-size SoA spectrum buffers
/// (`n/2 × LANES` each, the packed complex lanes) plus one full-size
/// staging buffer (`n × LANES`, the spectral-domain lanes).
///
/// Allocate once and reuse across calls via the `*_with_scratch` drivers
/// — the serving executors hold one per worker thread so the steady-state
/// hot path performs no allocation at all.
#[derive(Debug)]
pub struct PanelScratch {
    n: usize,
    zre: Vec<f32>,
    zim: Vec<f32>,
    t: Vec<f32>,
}

impl PanelScratch {
    /// Scratch for panels of size `n`.
    pub fn new(n: usize) -> PanelScratch {
        let h = (n / 2).max(1);
        PanelScratch {
            n,
            zre: vec![0.0; h * LANES],
            zim: vec![0.0; h * LANES],
            t: vec![0.0; n * LANES],
        }
    }

    /// Grow (never shrink) to serve panels of size `n`.
    pub fn ensure(&mut self, n: usize) {
        if n > self.n {
            *self = PanelScratch::new(n);
        }
    }
}

/// Batched SoA executor over a shared [`DctPlan`].
///
/// ```
/// use acdc::dct::{naive_dct2, BatchEngine};
/// let engine = BatchEngine::for_size(8);
/// let mut data = vec![0.0f32; 3 * 8];
/// data[0] = 1.0; // row 0 = impulse
/// let want = naive_dct2(&data[..8]);
/// engine.dct2_rows(&mut data, 3);
/// for k in 0..8 {
///     assert!((data[k] - want[k]).abs() < 1e-4);
/// }
/// ```
#[derive(Debug, Clone)]
pub struct BatchEngine {
    plan: Arc<DctPlan>,
    dispatch: &'static Dispatch,
}

impl BatchEngine {
    /// Engine over an existing plan handle, using the process-wide
    /// [`simd::active`] kernel dispatch.
    pub fn new(plan: Arc<DctPlan>) -> BatchEngine {
        BatchEngine::with_dispatch(plan, simd::active())
    }

    /// Engine pinned to an explicit kernel arm ([`simd::scalar`] /
    /// [`simd::avx2`]) — tests and benches compare arms through this.
    pub fn with_dispatch(plan: Arc<DctPlan>, dispatch: &'static Dispatch) -> BatchEngine {
        BatchEngine { plan, dispatch }
    }

    /// Engine over the process-wide cached plan for `n`.
    pub fn for_size(n: usize) -> BatchEngine {
        BatchEngine::new(PlanCache::get(n))
    }

    /// Transform size N.
    pub fn n(&self) -> usize {
        self.plan.len()
    }

    /// The underlying shared plan.
    pub fn plan(&self) -> &Arc<DctPlan> {
        &self.plan
    }

    /// The kernel arm this engine runs (`"scalar"` or `"avx2"`).
    pub fn dispatch_name(&self) -> &'static str {
        self.dispatch.name()
    }

    // -- batch drivers ------------------------------------------------------

    /// Orthonormal DCT-II of every row of `data` (`[rows, n]` row-major),
    /// in place, through SoA panels.
    pub fn dct2_rows(&self, data: &mut [f32], rows: usize) {
        let mut s = PanelScratch::new(self.n());
        self.dct2_rows_with_scratch(data, rows, &mut s);
    }

    /// [`BatchEngine::dct2_rows`] reusing caller-owned scratch (the
    /// allocation-free serving path).
    pub fn dct2_rows_with_scratch(&self, data: &mut [f32], rows: usize, s: &mut PanelScratch) {
        let n = self.n();
        assert_eq!(data.len(), rows * n, "data len vs rows × n");
        s.ensure(n);
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.dct2_panel(data, r, take, s);
            r += take;
        }
    }

    /// Orthonormal DCT-III (inverse of [`BatchEngine::dct2_rows`]) of
    /// every row of `data`, in place, through SoA panels.
    pub fn dct3_rows(&self, data: &mut [f32], rows: usize) {
        let mut s = PanelScratch::new(self.n());
        self.dct3_rows_with_scratch(data, rows, &mut s);
    }

    /// [`BatchEngine::dct3_rows`] reusing caller-owned scratch.
    pub fn dct3_rows_with_scratch(&self, data: &mut [f32], rows: usize, s: &mut PanelScratch) {
        let n = self.n();
        assert_eq!(data.len(), rows * n, "data len vs rows × n");
        s.ensure(n);
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.dct3_panel(data, r, take, s);
            r += take;
        }
    }

    /// Fused `ACDC⁻¹` layer over a batch:
    /// `out[r] = ((x[r] ⊙ a)·C ⊙ d + bias)·Cᵀ` for every row, one panel
    /// load and one panel store of main-memory traffic (§5's 8N bytes per
    /// row once `a`/`d`/`bias` are cache-resident).
    pub fn acdc_rows(
        &self,
        a: &[f32],
        d: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
        rows: usize,
    ) {
        let mut s = PanelScratch::new(self.n());
        self.acdc_rows_with_scratch(a, d, bias, x, out, rows, &mut s);
    }

    /// [`BatchEngine::acdc_rows`] reusing caller-owned scratch — the
    /// zero-allocation serving hot path.
    #[allow(clippy::too_many_arguments)]
    pub fn acdc_rows_with_scratch(
        &self,
        a: &[f32],
        d: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
        rows: usize,
        s: &mut PanelScratch,
    ) {
        let n = self.n();
        assert_eq!(a.len(), n);
        assert_eq!(d.len(), n);
        assert_eq!(bias.len(), n);
        assert_eq!(x.len(), rows * n, "x len vs rows × n");
        assert_eq!(out.len(), rows * n, "out len vs rows × n");
        s.ensure(n);
        let mut r = 0;
        while r < rows {
            let take = LANES.min(rows - r);
            self.acdc_panel(a, d, bias, x, out, r, take, s);
            r += take;
        }
    }

    /// [`BatchEngine::acdc_rows`] with panels split across `pool` — the
    /// serving path's thread-level parallelism. Falls back to the serial
    /// driver when the batch or pool is too small to amortize dispatch.
    #[allow(clippy::too_many_arguments)]
    pub fn acdc_rows_parallel(
        &self,
        a: &[f32],
        d: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
        rows: usize,
        pool: &ThreadPool,
    ) {
        let n = self.n();
        assert_eq!(a.len(), n);
        assert_eq!(d.len(), n);
        assert_eq!(bias.len(), n);
        assert_eq!(x.len(), rows * n, "x len vs rows × n");
        assert_eq!(out.len(), rows * n, "out len vs rows × n");
        let panels = rows.div_ceil(LANES);
        let parts = pool.size().min(panels);
        if parts <= 1 {
            return self.acdc_rows(a, d, bias, x, out, rows);
        }
        // Contiguous, disjoint row ranges on panel boundaries.
        let row_ranges: Vec<std::ops::Range<usize>> = split_ranges(panels, parts)
            .into_iter()
            .map(|p| (p.start * LANES)..(p.end * LANES).min(rows))
            .collect();
        struct Bufs {
            x: *const f32,
            out: *mut f32,
            a: *const f32,
            d: *const f32,
            bias: *const f32,
        }
        // SAFETY: the pointers are only dereferenced inside pool jobs, and
        // `ThreadPool::map` joins every job before returning, so the
        // borrows cannot outlive this call's slice arguments.
        unsafe impl Send for Bufs {}
        unsafe impl Sync for Bufs {}
        let bufs = Arc::new(Bufs {
            x: x.as_ptr(),
            out: out.as_mut_ptr(),
            a: a.as_ptr(),
            d: d.as_ptr(),
            bias: bias.as_ptr(),
        });
        let engine = self.clone();
        let ranges = Arc::new(row_ranges);
        pool.map(parts, move |i| {
            let r = ranges[i].clone();
            let count = r.end - r.start;
            // SAFETY: ranges are pairwise disjoint, so each job builds the
            // only mutable view of its own output rows; the shared input
            // and parameter views are read-only. All stay within the
            // caller's buffers (r.end ≤ rows) and die before `map` returns.
            let (x_part, out_part, a_v, d_v, bias_v) = unsafe {
                (
                    std::slice::from_raw_parts(bufs.x.add(r.start * n), count * n),
                    std::slice::from_raw_parts_mut(bufs.out.add(r.start * n), count * n),
                    std::slice::from_raw_parts(bufs.a, n),
                    std::slice::from_raw_parts(bufs.d, n),
                    std::slice::from_raw_parts(bufs.bias, n),
                )
            };
            engine.acdc_rows(a_v, d_v, bias_v, x_part, out_part, count);
        });
    }

    // -- panel kernels ------------------------------------------------------

    /// Makhoul pack + transpose of rows `r0..r0+take` straight into the
    /// half-size complex lanes: `z[j] = v[2j] + i·v[2j+1]` with
    /// `v[p] = row[src[p]]` (the plan's even/odd source table), optionally
    /// fusing a per-element `scale` (the ACDC `a` diagonal). Unused lanes
    /// are zero-filled, so padded tail panels stay exact.
    fn pack(&self, x: &[f32], r0: usize, take: usize, scale: Option<&[f32]>, s: &mut PanelScratch) {
        let n = self.n();
        let hl = ((n / 2).max(1)) * LANES;
        s.zre[..hl].fill(0.0);
        s.zim[..hl].fill(0.0);
        if n == 1 {
            for l in 0..take {
                s.zre[l] = x[r0 + l] * scale.map_or(1.0, |a| a[0]);
            }
            return;
        }
        let h = n / 2;
        let src = self.plan.rfft.src();
        for l in 0..take {
            let row = &x[(r0 + l) * n..(r0 + l + 1) * n];
            match scale {
                Some(a) => {
                    for j in 0..h {
                        let p0 = src[2 * j] as usize;
                        let p1 = src[2 * j + 1] as usize;
                        s.zre[j * LANES + l] = row[p0] * a[p0];
                        s.zim[j * LANES + l] = row[p1] * a[p1];
                    }
                }
                None => {
                    for j in 0..h {
                        s.zre[j * LANES + l] = row[src[2 * j] as usize];
                        s.zim[j * LANES + l] = row[src[2 * j + 1] as usize];
                    }
                }
            }
        }
    }

    /// Inverse of [`BatchEngine::pack`]: interleave the half-size complex
    /// lanes back into rows `r0..r0+take` of `out` through the same
    /// source table (`row[src[2j]] = Re z[j]`, `row[src[2j+1]] = Im z[j]`).
    fn unpack(&self, s: &PanelScratch, out: &mut [f32], r0: usize, take: usize) {
        let n = self.n();
        if n == 1 {
            for l in 0..take {
                out[r0 + l] = s.zre[l];
            }
            return;
        }
        let h = n / 2;
        let src = self.plan.rfft.src();
        for l in 0..take {
            let row = &mut out[(r0 + l) * n..(r0 + l + 1) * n];
            for j in 0..h {
                row[src[2 * j] as usize] = s.zre[j * LANES + l];
                row[src[2 * j + 1] as usize] = s.zim[j * LANES + l];
            }
        }
    }

    /// Forward twist stage tables (the DCT-II post-twiddle).
    fn fwd_stage<'a>(&'a self, d: Option<&'a [f32]>, bias: Option<&'a [f32]>) -> RealStage<'a> {
        let (_, twr, twi) = self.plan.fft.tables();
        RealStage {
            n: self.n(),
            c_re: &self.plan.fw_re,
            c_im: &self.plan.fw_im,
            tw_re: twr,
            tw_im: twi,
            d,
            bias,
        }
    }

    /// Inverse twist stage tables (the DCT-III pre-twiddle).
    fn inv_stage(&self) -> RealStage<'_> {
        let (_, twr, twi) = self.plan.fft.tables();
        RealStage {
            n: self.n(),
            c_re: &self.plan.bw_re,
            c_im: &self.plan.bw_im,
            tw_re: twr,
            tw_im: twi,
            d: None,
            bias: None,
        }
    }

    /// DCT-II of one panel, in place in `data`.
    fn dct2_panel(&self, data: &mut [f32], r0: usize, take: usize, s: &mut PanelScratch) {
        let n = self.n();
        if n == 1 {
            return; // 1-point orthonormal DCT is the identity
        }
        let h = n / 2;
        let (rev, twr, twi) = self.plan.rfft.half().tables();
        self.pack(data, r0, take, None, s);
        (self.dispatch.fft_soa)(
            &mut s.zre[..h * LANES],
            &mut s.zim[..h * LANES],
            h,
            rev,
            twr,
            twi,
            false,
        );
        (self.dispatch.real_fwd)(
            &self.fwd_stage(None, None),
            &s.zre[..h * LANES],
            &s.zim[..h * LANES],
            &mut s.t[..n * LANES],
        );
        // Plain transpose out (frequency order, no Makhoul reorder).
        for l in 0..take {
            let row = &mut data[(r0 + l) * n..(r0 + l + 1) * n];
            for (k, v) in row.iter_mut().enumerate() {
                *v = s.t[k * LANES + l];
            }
        }
    }

    /// DCT-III of one panel, in place in `data`.
    fn dct3_panel(&self, data: &mut [f32], r0: usize, take: usize, s: &mut PanelScratch) {
        let n = self.n();
        if n == 1 {
            return;
        }
        let h = n / 2;
        let (rev, twr, twi) = self.plan.rfft.half().tables();
        // Plain transpose in (zero the padded lanes).
        s.t[..n * LANES].fill(0.0);
        for l in 0..take {
            let row = &data[(r0 + l) * n..(r0 + l + 1) * n];
            for (k, &v) in row.iter().enumerate() {
                s.t[k * LANES + l] = v;
            }
        }
        (self.dispatch.real_inv)(
            &self.inv_stage(),
            &s.t[..n * LANES],
            &mut s.zre[..h * LANES],
            &mut s.zim[..h * LANES],
        );
        (self.dispatch.fft_soa)(
            &mut s.zre[..h * LANES],
            &mut s.zim[..h * LANES],
            h,
            rev,
            twr,
            twi,
            true,
        );
        self.unpack(s, data, r0, take);
    }

    /// One fused `ACDC⁻¹` panel: pack(⊙a) → FFT(N/2) → un-twist +
    /// post-twiddle ⊙d +bias → pre-twiddle + twist → IFFT(N/2) → unpack.
    /// All intermediates stay in `s`.
    #[allow(clippy::too_many_arguments)]
    fn acdc_panel(
        &self,
        a: &[f32],
        d: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
        r0: usize,
        take: usize,
        s: &mut PanelScratch,
    ) {
        let n = self.n();
        if n == 1 {
            // All transforms are the identity at n=1: y = x·a·d + bias.
            for l in 0..take {
                out[r0 + l] = (x[r0 + l] * a[0]) * d[0] + bias[0];
            }
            return;
        }
        let h = n / 2;
        let (rev, twr, twi) = self.plan.rfft.half().tables();
        self.pack(x, r0, take, Some(a), s);
        (self.dispatch.fft_soa)(
            &mut s.zre[..h * LANES],
            &mut s.zim[..h * LANES],
            h,
            rev,
            twr,
            twi,
            false,
        );
        (self.dispatch.real_fwd)(
            &self.fwd_stage(Some(d), Some(bias)),
            &s.zre[..h * LANES],
            &s.zim[..h * LANES],
            &mut s.t[..n * LANES],
        );
        (self.dispatch.real_inv)(
            &self.inv_stage(),
            &s.t[..n * LANES],
            &mut s.zre[..h * LANES],
            &mut s.zim[..h * LANES],
        );
        (self.dispatch.fft_soa)(
            &mut s.zre[..h * LANES],
            &mut s.zim[..h * LANES],
            h,
            rev,
            twr,
            twi,
            true,
        );
        self.unpack(s, out, r0, take);
    }
}

/// Shared lane block at bin `k` as a fixed-size array reference (the
/// known length lets LLVM elide bounds checks and vectorize the 8-wide
/// lane loops).
#[inline]
pub(crate) fn lane(buf: &[f32], k: usize) -> &[f32; LANES] {
    (&buf[k * LANES..(k + 1) * LANES]).try_into().unwrap()
}

/// Mutable lane block at bin `k` as a fixed-size array reference.
#[inline]
pub(crate) fn lane_mut(buf: &mut [f32], k: usize) -> &mut [f32; LANES] {
    (&mut buf[k * LANES..(k + 1) * LANES]).try_into().unwrap()
}

/// Two disjoint mutable lane blocks at bins `k < m` of one SoA buffer.
#[inline]
pub(crate) fn lane_pair(
    buf: &mut [f32],
    k: usize,
    m: usize,
) -> (&mut [f32; LANES], &mut [f32; LANES]) {
    debug_assert!(k < m);
    let (head, tail) = buf.split_at_mut(m * LANES);
    (
        (&mut head[k * LANES..(k + 1) * LANES]).try_into().unwrap(),
        (&mut tail[..LANES]).try_into().unwrap(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::{naive_dct2, naive_dct3};
    use crate::util::rng::Pcg32;

    #[test]
    fn plan_cache_shares_one_plan_per_size() {
        let a = PlanCache::get(32);
        let b = PlanCache::get(32);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(PlanCache::cached_sizes().contains(&32));
    }

    #[test]
    fn dct2_rows_matches_oracle_across_panel_shapes() {
        let mut rng = Pcg32::seeded(1);
        for n in [1usize, 2, 8, 64] {
            let engine = BatchEngine::for_size(n);
            for rows in [1usize, 3, 8, 9, 16, 17] {
                let orig = rng.normal_vec(rows * n, 0.0, 1.0);
                let mut data = orig.clone();
                engine.dct2_rows(&mut data, rows);
                for r in 0..rows {
                    let want = naive_dct2(&orig[r * n..(r + 1) * n]);
                    for k in 0..n {
                        assert!(
                            (data[r * n + k] - want[k]).abs() < 1e-4,
                            "n={n} rows={rows} r={r} k={k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn dct3_rows_matches_oracle() {
        let mut rng = Pcg32::seeded(2);
        for n in [2usize, 8, 64] {
            let engine = BatchEngine::for_size(n);
            for rows in [1usize, 5, 11] {
                let orig = rng.normal_vec(rows * n, 0.0, 1.0);
                let mut data = orig.clone();
                engine.dct3_rows(&mut data, rows);
                for r in 0..rows {
                    let want = naive_dct3(&orig[r * n..(r + 1) * n]);
                    for k in 0..n {
                        assert!(
                            (data[r * n + k] - want[k]).abs() < 1e-4,
                            "n={n} rows={rows} r={r} k={k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn soa_roundtrip_dct3_of_dct2_is_identity() {
        let mut rng = Pcg32::seeded(3);
        for n in [2usize, 16, 128] {
            let engine = BatchEngine::for_size(n);
            let rows = 13;
            let orig = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut data = orig.clone();
            engine.dct2_rows(&mut data, rows);
            engine.dct3_rows(&mut data, rows);
            for i in 0..rows * n {
                assert!((data[i] - orig[i]).abs() < 1e-4, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn fused_acdc_matches_unfused_chain() {
        let mut rng = Pcg32::seeded(4);
        for n in [2usize, 8, 64, 256] {
            let engine = BatchEngine::for_size(n);
            let rows = 9;
            let a = rng.normal_vec(n, 1.0, 0.3);
            let d = rng.normal_vec(n, 1.0, 0.3);
            let bias = rng.normal_vec(n, 0.0, 0.2);
            let x = rng.normal_vec(rows * n, 0.0, 1.0);
            let mut got = vec![0.0f32; rows * n];
            engine.acdc_rows(&a, &d, &bias, &x, &mut got, rows);
            // Unfused: scale, dct2_rows, scale+bias, dct3_rows.
            let mut want: Vec<f32> = x
                .chunks(n)
                .flat_map(|row| row.iter().zip(&a).map(|(&v, &av)| v * av))
                .collect();
            engine.dct2_rows(&mut want, rows);
            for r in 0..rows {
                for k in 0..n {
                    want[r * n + k] = want[r * n + k] * d[k] + bias[k];
                }
            }
            engine.dct3_rows(&mut want, rows);
            for i in 0..rows * n {
                assert!((got[i] - want[i]).abs() < 1e-4, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg32::seeded(5);
        let n = 64;
        let rows = 67; // several panels + ragged tail
        let engine = BatchEngine::for_size(n);
        let a = rng.normal_vec(n, 1.0, 0.2);
        let d = rng.normal_vec(n, 1.0, 0.2);
        let bias = rng.normal_vec(n, 0.0, 0.2);
        let x = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut serial = vec![0.0f32; rows * n];
        engine.acdc_rows(&a, &d, &bias, &x, &mut serial, rows);
        let pool = ThreadPool::new(4);
        let mut parallel = vec![0.0f32; rows * n];
        engine.acdc_rows_parallel(&a, &d, &bias, &x, &mut parallel, rows, &pool);
        assert_eq!(serial, parallel, "panel split must be bit-identical");
    }

    #[test]
    fn parallel_small_batch_falls_back_to_serial() {
        let mut rng = Pcg32::seeded(6);
        let n = 16;
        let rows = 3;
        let engine = BatchEngine::for_size(n);
        let a = vec![1.0; n];
        let d = vec![1.0; n];
        let bias = vec![0.0; n];
        let x = rng.normal_vec(rows * n, 0.0, 1.0);
        let pool = ThreadPool::new(4);
        let mut out = vec![0.0f32; rows * n];
        engine.acdc_rows_parallel(&a, &d, &bias, &x, &mut out, rows, &pool);
        // identity layer → output equals input
        for i in 0..rows * n {
            assert!((out[i] - x[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn size_one_engine_is_exact() {
        let engine = BatchEngine::for_size(1);
        let mut data = vec![2.0f32, -3.0, 0.5];
        engine.dct2_rows(&mut data, 3);
        assert_eq!(data, vec![2.0, -3.0, 0.5]); // 1-point orthonormal DCT = id
        let a = vec![2.0f32];
        let d = vec![0.5f32];
        let bias = vec![1.0f32];
        let x = vec![3.0f32, 4.0];
        let mut out = vec![0.0f32; 2];
        engine.acdc_rows(&a, &d, &bias, &x, &mut out, 2);
        // y = x·a·d + bias (all transforms identity at n=1)
        assert!((out[0] - 4.0).abs() < 1e-6);
        assert!((out[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn scalar_and_soa_paths_agree() {
        // The two execution strategies must be numerically interchangeable.
        let mut rng = Pcg32::seeded(7);
        let n = 128;
        let rows = 10;
        let plan = PlanCache::get(n);
        let engine = BatchEngine::new(Arc::clone(&plan));
        let orig = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut soa = orig.clone();
        engine.dct2_rows(&mut soa, rows);
        let mut scalar = orig;
        plan.dct2_rows(&mut scalar, rows);
        for i in 0..rows * n {
            assert!((soa[i] - scalar[i]).abs() < 1e-4, "i={i}");
        }
    }

    #[test]
    fn reused_scratch_is_bit_identical_to_fresh() {
        let mut rng = Pcg32::seeded(8);
        let n = 32;
        let rows = 11;
        let engine = BatchEngine::for_size(n);
        let a = rng.normal_vec(n, 1.0, 0.2);
        let d = rng.normal_vec(n, 1.0, 0.2);
        let bias = rng.normal_vec(n, 0.0, 0.2);
        let mut s = PanelScratch::new(n);
        let mut out_fresh = vec![0.0f32; rows * n];
        let mut out_reused = vec![0.0f32; rows * n];
        for trial in 0..3 {
            let x = rng.normal_vec(rows * n, 0.0, 1.0);
            engine.acdc_rows(&a, &d, &bias, &x, &mut out_fresh, rows);
            engine.acdc_rows_with_scratch(&a, &d, &bias, &x, &mut out_reused, rows, &mut s);
            assert_eq!(out_fresh, out_reused, "trial {trial}");
        }
        // Scratch grows across sizes without losing correctness.
        s.ensure(64);
        let engine64 = BatchEngine::for_size(64);
        let x = rng.normal_vec(64, 0.0, 1.0);
        let mut got = vec![0.0f32; 64];
        engine64.acdc_rows_with_scratch(
            &vec![1.0; 64],
            &vec![1.0; 64],
            &vec![0.0; 64],
            &x,
            &mut got,
            1,
            &mut s,
        );
        for i in 0..64 {
            assert!((got[i] - x[i]).abs() < 1e-4, "identity layer via grown scratch");
        }
    }

    #[test]
    fn scalar_dispatch_engine_matches_active() {
        let mut rng = Pcg32::seeded(9);
        let n = 64;
        let rows = 9;
        let plan = PlanCache::get(n);
        let active = BatchEngine::new(Arc::clone(&plan));
        let scalar = BatchEngine::with_dispatch(Arc::clone(&plan), crate::dct::simd::scalar());
        let a = rng.normal_vec(n, 1.0, 0.2);
        let d = rng.normal_vec(n, 1.0, 0.2);
        let bias = rng.normal_vec(n, 0.0, 0.2);
        let x = rng.normal_vec(rows * n, 0.0, 1.0);
        let mut got_a = vec![0.0f32; rows * n];
        let mut got_s = vec![0.0f32; rows * n];
        active.acdc_rows(&a, &d, &bias, &x, &mut got_a, rows);
        scalar.acdc_rows(&a, &d, &bias, &x, &mut got_s, rows);
        // The SIMD arms are mul/add-only in scalar op order → bit-identical.
        for (va, vs) in got_a.iter().zip(&got_s) {
            assert_eq!(va.to_bits(), vs.to_bits());
        }
    }
}
