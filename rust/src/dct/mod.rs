//! DCT-II / DCT-III (orthonormal) with four implementations:
//!
//! * `DctPlan::dct2 / dct3` — scalar O(N log N) via Makhoul (1980),
//!   computed through a **real-input** N/2-point FFT
//!   ([`fft::RealFftPlan`]: pack-into-complex + un-twist), half the
//!   butterflies of the previous complex-FFT route;
//! * [`batch`] — the batched structure-of-arrays engine: the same
//!   real-FFT Makhoul schedule run 8 rows per pass with the ACDC
//!   diagonals fused into the twist stages (DESIGN.md §4) and runtime
//!   SIMD dispatch ([`simd`]), plus the process-wide [`PlanCache`];
//! * `DctPlan::matrix` — O(N²) matmul against the precomputed DCT
//!   matrix (what the Pallas kernel does on the MXU);
//! * `naive_dct2 / naive_dct3` — O(N²) f64 closed-form oracles used only
//!   in tests.
//!
//! All use the paper's eq. (9) orthonormal scaling, so `dct3(dct2(x)) == x`
//! and the transform matrix is orthogonal.

pub mod batch;
pub mod fft;
pub mod simd;

pub use batch::{BatchEngine, PanelScratch, PlanCache, LANES, MIN_SOA_ROWS};

use fft::{FftPlan, RealFftPlan};

/// Precomputed plan for orthonormal DCT-II/III of a fixed size.
#[derive(Debug, Clone)]
pub struct DctPlan {
    n: usize,
    fft: FftPlan,
    /// Half-size real-input FFT plan (the Makhoul pack), shared by the
    /// scalar single-row path and the SoA panel engine.
    rfft: RealFftPlan,
    /// Forward post-twiddle: 2·e^{-iπk/(2N)} scaled by sqrt(2/N)·ε_k / 2.
    fw_re: Vec<f32>,
    fw_im: Vec<f32>,
    /// Inverse pre-twiddle: e^{iπk/(2N)} / (sqrt(2/N)·ε_k).
    bw_re: Vec<f32>,
    bw_im: Vec<f32>,
    /// Orthonormal DCT-II matrix (row-major [n, n]; y = x @ C), built lazily.
    matrix: std::sync::OnceLock<Vec<f32>>,
}

impl DctPlan {
    /// Build a plan for size `n` (must be a power of two, like the
    /// paper's implementations).
    ///
    /// ```
    /// use acdc::dct::{naive_dct2, DctPlan};
    /// let plan = DctPlan::new(8);
    /// let mut x: Vec<f32> = (0..8).map(|i| i as f32).collect();
    /// let want = naive_dct2(&x);
    /// let mut scratch = vec![0.0f32; 16]; // 2·n re/im scratch
    /// plan.dct2(&mut x, &mut scratch);
    /// assert!((x[0] - want[0]).abs() < 1e-4);
    /// plan.dct3(&mut x, &mut scratch); // inverse: back to the ramp
    /// assert!((x[3] - 3.0).abs() < 1e-4);
    /// ```
    pub fn new(n: usize) -> DctPlan {
        assert!(n.is_power_of_two(), "DCT size must be a power of two, got {n}");
        let mut fw_re = Vec::with_capacity(n);
        let mut fw_im = Vec::with_capacity(n);
        let mut bw_re = Vec::with_capacity(n);
        let mut bw_im = Vec::with_capacity(n);
        for k in 0..n {
            let ang = -std::f64::consts::PI * k as f64 / (2.0 * n as f64);
            let eps = if k == 0 {
                1.0 / 2.0_f64.sqrt()
            } else {
                1.0
            };
            let scale = (2.0 / n as f64).sqrt() * eps;
            // Forward: X[k] = scale * Re(e^{-iπk/2N} · V[k])
            fw_re.push((scale * ang.cos()) as f32);
            fw_im.push((scale * ang.sin()) as f32);
            // Inverse: V[k] = e^{+iπk/2N} · (X[k]/scale  - i X[N-k]/scale')
            let inv_scale = 1.0 / scale;
            bw_re.push(((-ang).cos() * inv_scale) as f32);
            bw_im.push(((-ang).sin() * inv_scale) as f32);
        }
        DctPlan {
            n,
            fft: FftPlan::new(n),
            rfft: RealFftPlan::new(n),
            fw_re,
            fw_im,
            bw_re,
            bw_im,
            matrix: std::sync::OnceLock::new(),
        }
    }

    /// Transform size N.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True only for a degenerate zero-length plan (never constructed by
    /// [`DctPlan::new`]).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Orthonormal DCT-II of `x` in place (paper's `h2 = h1 · C`).
    ///
    /// Makhoul's trick on a **real-input** FFT: reorder even/odd, pack the
    /// N reals into an N/2 complex FFT, then un-twist + post-twiddle in
    /// one O(N) sweep ([`fft::RealFftPlan`]) — half the butterflies of the
    /// previous complex-FFT route. `scratch` must be ≥ 2·n long (the real
    /// path uses the first n floats as the packed re/im halves).
    pub fn dct2(&self, x: &mut [f32], scratch: &mut [f32]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert!(scratch.len() >= 2 * n);
        if n == 1 {
            return; // 1-point orthonormal DCT is the identity
        }
        let h = n / 2;
        let src = self.rfft.src();
        let (zre, rest) = scratch.split_at_mut(h);
        let zim = &mut rest[..h];
        // z[j] = v[2j] + i·v[2j+1] with v[p] = x[src[p]] (Makhoul reorder).
        for j in 0..h {
            zre[j] = x[src[2 * j] as usize];
            zim[j] = x[src[2 * j + 1] as usize];
        }
        self.rfft.half().forward(zre, zim);
        let (_, twr, twi) = self.fft.tables();
        // Bins 0 and h: V[0] = ReZ0 + ImZ0, V[h] = ReZ0 - ImZ0 (both real).
        let v0 = zre[0] + zim[0];
        let vh = zre[0] - zim[0];
        // Un-twist + post-twiddle, Hermitian pickup for the top half.
        for k in 1..h {
            let kk = h - k;
            let zer = 0.5 * (zre[k] + zre[kk]);
            let zei = 0.5 * (zim[k] - zim[kk]);
            let zor = 0.5 * (zim[k] + zim[kk]);
            let zoi = -0.5 * (zre[k] - zre[kk]);
            let vr = zer + (twr[k] * zor - twi[k] * zoi);
            let vi = zei + (twr[k] * zoi + twi[k] * zor);
            x[k] = self.fw_re[k] * vr - self.fw_im[k] * vi;
            x[n - k] = self.fw_re[n - k] * vr + self.fw_im[n - k] * vi;
        }
        x[0] = self.fw_re[0] * v0;
        x[h] = self.fw_re[h] * vh;
    }

    /// Orthonormal DCT-III (inverse of `dct2`) of `x` in place, through
    /// the same half-size real-FFT path (pre-twiddle + twist down, one
    /// N/2 inverse FFT, interleave back via the Makhoul source table).
    pub fn dct3(&self, x: &mut [f32], scratch: &mut [f32]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert!(scratch.len() >= 2 * n);
        if n == 1 {
            return;
        }
        let h = n / 2;
        let (zre, rest) = scratch.split_at_mut(h);
        let zim = &mut rest[..h];
        let (_, twr, twi) = self.fft.tables();
        // V[j] = (bw_re + i·bw_im)[j] · (x[j] - i·x[n-j])  (x[n] ≡ 0),
        // then twist the Hermitian V down to the half spectrum Z.
        for k in 0..h {
            let hk = h - k; // 1..=h — x[n - hk] is always in range
            let xk = x[k];
            let xnk = if k == 0 { 0.0 } else { x[n - k] };
            let vrk = self.bw_re[k] * xk + self.bw_im[k] * xnk;
            let vik = self.bw_im[k] * xk - self.bw_re[k] * xnk;
            let xhk = x[hk];
            let xnhk = x[n - hk];
            let vrh = self.bw_re[hk] * xhk + self.bw_im[hk] * xnhk;
            let vih = self.bw_im[hk] * xhk - self.bw_re[hk] * xnhk;
            let zer = 0.5 * (vrk + vrh);
            let zei = 0.5 * (vik - vih);
            let dr = 0.5 * (vrk - vrh);
            let di = 0.5 * (vik + vih);
            let zor = twr[k] * dr + twi[k] * di; // conj(tw)·D
            let zoi = twr[k] * di - twi[k] * dr;
            zre[k] = zer - zoi;
            zim[k] = zei + zor;
        }
        self.rfft.half().inverse(zre, zim);
        let src = self.rfft.src();
        for j in 0..h {
            x[src[2 * j] as usize] = zre[j];
            x[src[2 * j + 1] as usize] = zim[j];
        }
    }

    /// DCT-II of two rows through ONE complex FFT (the classic 2-for-1
    /// real-transform packing: FFT(v1 + i·v2), then separate the two
    /// Hermitian spectra). ~1.7× the throughput of two `dct2` calls —
    /// perf pass L1/L3 item, see EXPERIMENTS.md §Perf.
    pub fn dct2_pair(&self, x1: &mut [f32], x2: &mut [f32], scratch: &mut [f32]) {
        let n = self.n;
        assert_eq!(x1.len(), n);
        assert_eq!(x2.len(), n);
        assert!(scratch.len() >= 2 * n);
        let (re, rest) = scratch.split_at_mut(n);
        let im = &mut rest[..n];
        // Makhoul reorder of both rows into the real/imag lanes.
        for j in 0..n / 2 {
            re[j] = x1[2 * j];
            re[n - 1 - j] = x1[2 * j + 1];
            im[j] = x2[2 * j];
            im[n - 1 - j] = x2[2 * j + 1];
        }
        if n == 1 {
            re[0] = x1[0];
            im[0] = x2[0];
        }
        self.fft.forward(re, im);
        // Separate: F1[k] = (Z[k] + conj(Z[n-k]))/2, F2 = (Z[k] - conj(Z[n-k]))/(2i)
        for k in 0..n {
            let nk = if k == 0 { 0 } else { n - k };
            let (zr, zi) = (re[k], im[k]);
            let (cr, ci) = (re[nk], -im[nk]); // conj(Z[n-k])
            let f1 = (0.5 * (zr + cr), 0.5 * (zi + ci));
            let f2 = (0.5 * (zi - ci), -0.5 * (zr - cr)); // (Z - conj)/2i
            x1[k] = self.fw_re[k] * f1.0 - self.fw_im[k] * f1.1;
            x2[k] = self.fw_re[k] * f2.0 - self.fw_im[k] * f2.1;
        }
    }

    /// DCT-III of two rows through one complex inverse FFT (dual of
    /// `dct2_pair`: both pre-twiddled spectra ride one IFFT, the real
    /// and imaginary outputs are the two rows).
    pub fn dct3_pair(&self, x1: &mut [f32], x2: &mut [f32], scratch: &mut [f32]) {
        let n = self.n;
        assert_eq!(x1.len(), n);
        assert_eq!(x2.len(), n);
        assert!(scratch.len() >= 2 * n);
        let (re, rest) = scratch.split_at_mut(n);
        let im = &mut rest[..n];
        for k in 0..n {
            let x1k = x1[k];
            let x1nk = if k == 0 { 0.0 } else { x1[n - k] };
            let x2k = x2[k];
            let x2nk = if k == 0 { 0.0 } else { x2[n - k] };
            // V1[k] = tw·(x1[k] - i·x1[n-k]), V2[k] likewise; z = V1 + i·V2.
            let v1 = (
                self.bw_re[k] * x1k + self.bw_im[k] * x1nk,
                self.bw_im[k] * x1k - self.bw_re[k] * x1nk,
            );
            let v2 = (
                self.bw_re[k] * x2k + self.bw_im[k] * x2nk,
                self.bw_im[k] * x2k - self.bw_re[k] * x2nk,
            );
            re[k] = v1.0 - v2.1;
            im[k] = v1.1 + v2.0;
        }
        self.fft.inverse(re, im);
        for j in 0..n / 2 {
            x1[2 * j] = re[j];
            x1[2 * j + 1] = re[n - 1 - j];
            x2[2 * j] = im[j];
            x2[2 * j + 1] = im[n - 1 - j];
        }
        if n == 1 {
            x1[0] = re[0];
            x2[0] = im[0];
        }
    }

    /// Apply DCT-II to every row of a [rows, n] buffer (pairs rows
    /// through `dct2_pair` — see §Perf).
    pub fn dct2_rows(&self, data: &mut [f32], rows: usize) {
        let n = self.n;
        assert_eq!(data.len(), rows * n);
        let mut scratch = vec![0.0f32; 2 * n];
        let mut r = 0;
        while r + 1 < rows {
            let (a, b) = data[r * n..].split_at_mut(n);
            self.dct2_pair(a, &mut b[..n], &mut scratch);
            r += 2;
        }
        if r < rows {
            self.dct2(&mut data[r * n..(r + 1) * n], &mut scratch);
        }
    }

    /// Apply DCT-III to every row of a [rows, n] buffer (paired).
    pub fn dct3_rows(&self, data: &mut [f32], rows: usize) {
        let n = self.n;
        assert_eq!(data.len(), rows * n);
        let mut scratch = vec![0.0f32; 2 * n];
        let mut r = 0;
        while r + 1 < rows {
            let (a, b) = data[r * n..].split_at_mut(n);
            self.dct3_pair(a, &mut b[..n], &mut scratch);
            r += 2;
        }
        if r < rows {
            self.dct3(&mut data[r * n..(r + 1) * n], &mut scratch);
        }
    }

    /// The orthonormal DCT-II matrix C (row-major, `y = x @ C`), cached.
    pub fn matrix(&self) -> &[f32] {
        self.matrix.get_or_init(|| {
            let n = self.n;
            let mut c = vec![0.0f32; n * n];
            for j in 0..n {
                for k in 0..n {
                    c[j * n + k] = dct2_entry(n, j, k) as f32;
                }
            }
            c
        })
    }
}

/// Closed-form entry C[j,k] of the orthonormal DCT-II matrix (paper eq. 9).
fn dct2_entry(n: usize, j: usize, k: usize) -> f64 {
    let eps = if k == 0 { 1.0 / 2.0_f64.sqrt() } else { 1.0 };
    (2.0 / n as f64).sqrt()
        * eps
        * (std::f64::consts::PI * (2.0 * j as f64 + 1.0) * k as f64 / (2.0 * n as f64)).cos()
}

/// O(N²) f64 DCT-II oracle (tests only).
pub fn naive_dct2(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    (0..n)
        .map(|k| {
            (0..n)
                .map(|j| x[j] as f64 * dct2_entry(n, j, k))
                .sum::<f64>() as f32
        })
        .collect()
}

/// O(N²) f64 DCT-III oracle (tests only): y = x @ Cᵀ.
pub fn naive_dct3(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    (0..n)
        .map(|j| {
            (0..n)
                .map(|k| x[k] as f64 * dct2_entry(n, j, k))
                .sum::<f64>() as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn dct2_matches_naive() {
        let mut rng = Pcg32::seeded(1);
        for n in [2usize, 4, 8, 32, 128, 512] {
            let plan = DctPlan::new(n);
            let x0 = rng.normal_vec(n, 0.0, 1.0);
            let want = naive_dct2(&x0);
            let mut x = x0.clone();
            let mut scratch = vec![0.0; 2 * n];
            plan.dct2(&mut x, &mut scratch);
            for i in 0..n {
                assert!(
                    (x[i] - want[i]).abs() < 2e-4 * (n as f32).sqrt(),
                    "n={n} i={i} got={} want={}",
                    x[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn dct3_matches_naive() {
        let mut rng = Pcg32::seeded(2);
        for n in [2usize, 8, 64, 256] {
            let plan = DctPlan::new(n);
            let x0 = rng.normal_vec(n, 0.0, 1.0);
            let want = naive_dct3(&x0);
            let mut x = x0.clone();
            let mut scratch = vec![0.0; 2 * n];
            plan.dct3(&mut x, &mut scratch);
            for i in 0..n {
                assert!(
                    (x[i] - want[i]).abs() < 2e-4 * (n as f32).sqrt(),
                    "n={n} i={i} got={} want={}",
                    x[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn roundtrip_dct2_dct3() {
        let mut rng = Pcg32::seeded(3);
        for n in [2usize, 16, 128, 1024, 4096] {
            let plan = DctPlan::new(n);
            let x0 = rng.normal_vec(n, 0.0, 1.0);
            let mut x = x0.clone();
            let mut scratch = vec![0.0; 2 * n];
            plan.dct2(&mut x, &mut scratch);
            plan.dct3(&mut x, &mut scratch);
            for i in 0..n {
                assert!((x[i] - x0[i]).abs() < 1e-3, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn matrix_is_orthogonal() {
        for n in [4usize, 16, 64] {
            let plan = DctPlan::new(n);
            let c = plan.matrix();
            // C·Cᵀ = I
            for i in 0..n {
                for j in 0..n {
                    let dot: f64 = (0..n)
                        .map(|k| c[i * n + k] as f64 * c[j * n + k] as f64)
                        .sum();
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-5, "n={n} ({i},{j}) dot={dot}");
                }
            }
        }
    }

    #[test]
    fn dct2_equals_matrix_product() {
        let mut rng = Pcg32::seeded(4);
        let n = 64;
        let plan = DctPlan::new(n);
        let x0 = rng.normal_vec(n, 0.0, 1.0);
        let c = plan.matrix().to_vec();
        let mut want = vec![0.0f32; n];
        crate::tensor::matvec_row(&x0, &c, &mut want, n, n);
        let mut x = x0;
        let mut scratch = vec![0.0; 2 * n];
        plan.dct2(&mut x, &mut scratch);
        for i in 0..n {
            assert!((x[i] - want[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn energy_preserved() {
        let mut rng = Pcg32::seeded(5);
        let n = 256;
        let plan = DctPlan::new(n);
        let x0 = rng.normal_vec(n, 0.0, 1.0);
        let e0: f64 = x0.iter().map(|v| (*v as f64).powi(2)).sum();
        let mut x = x0;
        let mut scratch = vec![0.0; 2 * n];
        plan.dct2(&mut x, &mut scratch);
        let e1: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!((e0 - e1).abs() / e0 < 1e-5);
    }

    #[test]
    fn dc_component() {
        // DCT-II of a constant vector: only k=0 nonzero, = const·sqrt(n).
        let n = 64;
        let plan = DctPlan::new(n);
        let mut x = vec![2.0f32; n];
        let mut scratch = vec![0.0; 2 * n];
        plan.dct2(&mut x, &mut scratch);
        assert!((x[0] - 2.0 * (n as f32).sqrt()).abs() < 1e-3);
        for i in 1..n {
            assert!(x[i].abs() < 1e-4, "i={i} -> {}", x[i]);
        }
    }

    #[test]
    fn rows_apply_independently() {
        let mut rng = Pcg32::seeded(6);
        let n = 32;
        let rows = 5;
        let plan = DctPlan::new(n);
        let mut data = rng.normal_vec(rows * n, 0.0, 1.0);
        let orig = data.clone();
        plan.dct2_rows(&mut data, rows);
        for r in 0..rows {
            let want = naive_dct2(&orig[r * n..(r + 1) * n]);
            for i in 0..n {
                assert!((data[r * n + i] - want[i]).abs() < 1e-3);
            }
        }
        plan.dct3_rows(&mut data, rows);
        for i in 0..rows * n {
            assert!((data[i] - orig[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn size_two_closed_form() {
        // n=2 orthonormal DCT-II: y0=(x0+x1)/√2, y1=(x0-x1)/√2·cos(π/4)·√2 …
        let plan = DctPlan::new(2);
        let mut x = vec![1.0f32, 0.0];
        let mut scratch = vec![0.0; 4];
        plan.dct2(&mut x, &mut scratch);
        let want = naive_dct2(&[1.0, 0.0]);
        assert!((x[0] - want[0]).abs() < 1e-6);
        assert!((x[1] - want[1]).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        DctPlan::new(12);
    }

    #[test]
    fn dct2_pair_matches_two_singles() {
        let mut rng = Pcg32::seeded(7);
        for n in [2usize, 8, 64, 256] {
            let plan = DctPlan::new(n);
            let a0 = rng.normal_vec(n, 0.0, 1.0);
            let b0 = rng.normal_vec(n, 0.0, 1.0);
            let mut scratch = vec![0.0; 2 * n];
            let (mut a_want, mut b_want) = (a0.clone(), b0.clone());
            plan.dct2(&mut a_want, &mut scratch);
            plan.dct2(&mut b_want, &mut scratch);
            let (mut a, mut b) = (a0, b0);
            plan.dct2_pair(&mut a, &mut b, &mut scratch);
            for i in 0..n {
                assert!((a[i] - a_want[i]).abs() < 1e-3, "n={n} i={i} lane1");
                assert!((b[i] - b_want[i]).abs() < 1e-3, "n={n} i={i} lane2");
            }
        }
    }

    #[test]
    fn dct3_pair_matches_two_singles() {
        let mut rng = Pcg32::seeded(8);
        for n in [2usize, 8, 64, 256] {
            let plan = DctPlan::new(n);
            let a0 = rng.normal_vec(n, 0.0, 1.0);
            let b0 = rng.normal_vec(n, 0.0, 1.0);
            let mut scratch = vec![0.0; 2 * n];
            let (mut a_want, mut b_want) = (a0.clone(), b0.clone());
            plan.dct3(&mut a_want, &mut scratch);
            plan.dct3(&mut b_want, &mut scratch);
            let (mut a, mut b) = (a0, b0);
            plan.dct3_pair(&mut a, &mut b, &mut scratch);
            for i in 0..n {
                assert!((a[i] - a_want[i]).abs() < 1e-3, "n={n} i={i} lane1");
                assert!((b[i] - b_want[i]).abs() < 1e-3, "n={n} i={i} lane2");
            }
        }
    }

    #[test]
    fn paired_roundtrip() {
        let mut rng = Pcg32::seeded(9);
        let n = 128;
        let plan = DctPlan::new(n);
        let a0 = rng.normal_vec(n, 0.0, 1.0);
        let b0 = rng.normal_vec(n, 0.0, 1.0);
        let (mut a, mut b) = (a0.clone(), b0.clone());
        let mut scratch = vec![0.0; 2 * n];
        plan.dct2_pair(&mut a, &mut b, &mut scratch);
        plan.dct3_pair(&mut a, &mut b, &mut scratch);
        for i in 0..n {
            assert!((a[i] - a0[i]).abs() < 1e-3);
            assert!((b[i] - b0[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn rows_odd_count_uses_single_fallback() {
        let mut rng = Pcg32::seeded(10);
        let n = 32;
        let rows = 5; // odd → last row through the single path
        let plan = DctPlan::new(n);
        let mut data = rng.normal_vec(rows * n, 0.0, 1.0);
        let orig = data.clone();
        plan.dct2_rows(&mut data, rows);
        for r in 0..rows {
            let want = naive_dct2(&orig[r * n..(r + 1) * n]);
            for i in 0..n {
                assert!((data[r * n + i] - want[i]).abs() < 1e-3, "r={r}");
            }
        }
    }
}
