//! Iterative radix-2 complex FFT with precomputed plans.
//!
//! This is the rust analogue of the paper's cuFFT dependency (DESIGN.md
//! substitution S3): the multiple-call ACDC implementation computes its
//! DCTs via FFTs exactly as the paper's §5.2 does via Makhoul (1980).
//! Power-of-two sizes only — the paper's implementations have the same
//! restriction ("the implementation is constrained to power-of-two ...
//! layer sizes").

/// Precomputed FFT plan for a fixed power-of-two size.
#[derive(Debug, Clone)]
pub struct FftPlan {
    n: usize,
    /// Bit-reversal permutation.
    rev: Vec<u32>,
    /// Twiddles e^{-2πi j / n} for j in 0..n/2 (forward sign convention).
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl FftPlan {
    /// Build a plan; `n` must be a power of two ≥ 1.
    pub fn new(n: usize) -> FftPlan {
        assert!(n.is_power_of_two(), "FFT size must be a power of two, got {n}");
        let bits = n.trailing_zeros();
        let rev = (0..n as u32)
            .map(|i| i.reverse_bits() >> (32 - bits.max(1)) as u32)
            .map(|r| if n == 1 { 0 } else { r })
            .collect();
        let half = (n / 2).max(1);
        let mut tw_re = Vec::with_capacity(half);
        let mut tw_im = Vec::with_capacity(half);
        for j in 0..half {
            let ang = -2.0 * std::f64::consts::PI * j as f64 / n as f64;
            tw_re.push(ang.cos() as f32);
            tw_im.push(ang.sin() as f32);
        }
        FftPlan {
            n,
            rev,
            tw_re,
            tw_im,
        }
    }

    /// Transform size N.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True only for the degenerate zero-length plan (never constructed
    /// by [`FftPlan::new`], which requires a power of two ≥ 1).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Precomputed tables `(rev, tw_re, tw_im)` — the bit-reversal
    /// permutation and the n/2 forward twiddles. Shared with the
    /// structure-of-arrays batched engine ([`crate::dct::batch`]) so both
    /// execution strategies run the identical radix-2 schedule.
    pub(crate) fn tables(&self) -> (&[u32], &[f32], &[f32]) {
        (&self.rev, &self.tw_re, &self.tw_im)
    }

    /// In-place forward FFT over split re/im buffers of length n.
    pub fn forward(&self, re: &mut [f32], im: &mut [f32]) {
        self.transform(re, im, false);
    }

    /// In-place inverse FFT (includes the 1/n scaling).
    pub fn inverse(&self, re: &mut [f32], im: &mut [f32]) {
        self.transform(re, im, true);
        let inv = 1.0 / self.n as f32;
        for v in re.iter_mut() {
            *v *= inv;
        }
        for v in im.iter_mut() {
            *v *= inv;
        }
    }

    fn transform(&self, re: &mut [f32], im: &mut [f32], invert: bool) {
        let n = self.n;
        assert_eq!(re.len(), n);
        assert_eq!(im.len(), n);
        if n == 1 {
            return;
        }
        // Bit-reversal reorder.
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        // Danielson–Lanczos stages.
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len; // twiddle stride into the n/2 table
            for start in (0..n).step_by(len) {
                let mut tidx = 0;
                for k in start..start + half {
                    let wr = self.tw_re[tidx];
                    let wi = if invert {
                        -self.tw_im[tidx]
                    } else {
                        self.tw_im[tidx]
                    };
                    let m = k + half;
                    let xr = re[m] * wr - im[m] * wi;
                    let xi = re[m] * wi + im[m] * wr;
                    re[m] = re[k] - xr;
                    im[m] = im[k] - xi;
                    re[k] += xr;
                    im[k] += xi;
                    tidx += step;
                }
            }
            len <<= 1;
        }
    }
}

/// Real-input FFT plan: an N-point real transform computed through one
/// N/2-point **complex** FFT plus an O(N) un-twist stage (Makhoul 1980,
/// §3; the classic packing z[j] = v[2j] + i·v[2j+1]).
///
/// This halves the butterfly count and the FFT scratch traffic of every
/// DCT in the stack relative to the complex-FFT-with-zero-imaginary path
/// the scalar [`FftPlan`] route uses. The twist twiddles e^{∓2πik/N} are
/// exactly the *full-size* plan's twiddle table, so [`crate::dct::DctPlan`]
/// shares one table between its pair path and this real path.
///
/// The un-twist algebra (validated against f64 oracles in
/// `tests/property_realfft.rs`):
///
/// ```text
/// forward:  Z = FFT_{N/2}(z),  Ze = (Z[k]+conj(Z[h-k]))/2,
///           Zo = (Z[k]-conj(Z[h-k]))/2i,  V[k] = Ze + e^{-2πik/N}·Zo
/// inverse:  Ze = (V[k]+conj(V[h-k]))/2,
///           Zo = e^{+2πik/N}·(V[k]-conj(V[h-k]))/2,  Z = Ze + i·Zo
/// ```
#[derive(Debug, Clone)]
pub struct RealFftPlan {
    n: usize,
    /// The N/2-point complex plan both directions ride.
    half: FftPlan,
    /// Makhoul source table: v\[p\] = x\[src\[p\]\] (even indices ascending
    /// into the front half, odd indices descending into the back half).
    src: Vec<u32>,
}

impl RealFftPlan {
    /// Build a plan; `n` must be a power of two ≥ 1 (n = 1 degenerates to
    /// the identity, handled by callers before any FFT work).
    pub fn new(n: usize) -> RealFftPlan {
        assert!(n.is_power_of_two(), "real FFT size must be a power of two, got {n}");
        let mut src = vec![0u32; n];
        for p in 0..n / 2 {
            src[p] = 2 * p as u32;
            src[n - 1 - p] = 2 * p as u32 + 1;
        }
        if n == 1 {
            src[0] = 0;
        }
        RealFftPlan {
            n,
            half: FftPlan::new((n / 2).max(1)),
            src,
        }
    }

    /// Transform size N (the real length, not the half complex length).
    pub fn len(&self) -> usize {
        self.n
    }

    /// True only for a degenerate zero-length plan (never constructed by
    /// [`RealFftPlan::new`]).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The half-size complex plan (size N/2).
    pub(crate) fn half(&self) -> &FftPlan {
        &self.half
    }

    /// The Makhoul even/odd source-index table (`v[p] = x[src[p]]`).
    pub(crate) fn src(&self) -> &[u32] {
        &self.src
    }
}

/// Naive O(N²) DFT used as the FFT's test oracle (f64 accumulation).
pub fn naive_dft(re: &[f32], im: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let n = re.len();
    let mut or_ = vec![0.0f32; n];
    let mut oi = vec![0.0f32; n];
    for k in 0..n {
        let mut sr = 0.0f64;
        let mut si = 0.0f64;
        for t in 0..n {
            let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
            let (c, s) = (ang.cos(), ang.sin());
            sr += re[t] as f64 * c - im[t] as f64 * s;
            si += re[t] as f64 * s + im[t] as f64 * c;
        }
        or_[k] = sr as f32;
        oi[k] = si as f32;
    }
    (or_, oi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn size_one_is_identity() {
        let p = FftPlan::new(1);
        let mut re = vec![3.0];
        let mut im = vec![-1.0];
        p.forward(&mut re, &mut im);
        assert_eq!((re[0], im[0]), (3.0, -1.0));
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        FftPlan::new(12);
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = Pcg32::seeded(1);
        for n in [2usize, 4, 8, 64, 256] {
            let p = FftPlan::new(n);
            let re0 = rng.normal_vec(n, 0.0, 1.0);
            let im0 = rng.normal_vec(n, 0.0, 1.0);
            let (wr, wi) = naive_dft(&re0, &im0);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            p.forward(&mut re, &mut im);
            for i in 0..n {
                assert!((re[i] - wr[i]).abs() < 1e-3 * (n as f32).sqrt(), "n={n} i={i}");
                assert!((im[i] - wi[i]).abs() < 1e-3 * (n as f32).sqrt());
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        let mut rng = Pcg32::seeded(2);
        for n in [2usize, 16, 128, 1024] {
            let p = FftPlan::new(n);
            let re0 = rng.normal_vec(n, 0.0, 1.0);
            let im0 = rng.normal_vec(n, 0.0, 1.0);
            let (mut re, mut im) = (re0.clone(), im0.clone());
            p.forward(&mut re, &mut im);
            p.inverse(&mut re, &mut im);
            for i in 0..n {
                assert!((re[i] - re0[i]).abs() < 1e-4, "n={n}");
                assert!((im[i] - im0[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn impulse_gives_flat_spectrum() {
        let n = 64;
        let p = FftPlan::new(n);
        let mut re = vec![0.0; n];
        let mut im = vec![0.0; n];
        re[0] = 1.0;
        p.forward(&mut re, &mut im);
        for i in 0..n {
            assert!((re[i] - 1.0).abs() < 1e-5);
            assert!(im[i].abs() < 1e-5);
        }
    }

    #[test]
    fn constant_gives_dc_only() {
        let n = 32;
        let p = FftPlan::new(n);
        let mut re = vec![1.0; n];
        let mut im = vec![0.0; n];
        p.forward(&mut re, &mut im);
        assert!((re[0] - n as f32).abs() < 1e-4);
        for i in 1..n {
            assert!(re[i].abs() < 1e-4 && im[i].abs() < 1e-4);
        }
    }

    #[test]
    fn parseval_energy() {
        let mut rng = Pcg32::seeded(3);
        let n = 256;
        let p = FftPlan::new(n);
        let re0 = rng.normal_vec(n, 0.0, 1.0);
        let im0 = vec![0.0; n];
        let time: f64 = re0.iter().map(|v| (*v as f64).powi(2)).sum();
        let (mut re, mut im) = (re0, im0);
        p.forward(&mut re, &mut im);
        let freq: f64 = re
            .iter()
            .zip(&im)
            .map(|(r, i)| (*r as f64).powi(2) + (*i as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((time - freq).abs() / time < 1e-5);
    }

    #[test]
    fn real_plan_src_table_is_the_makhoul_reorder() {
        let p = RealFftPlan::new(8);
        assert_eq!(p.len(), 8);
        assert_eq!(p.half().len(), 4);
        // v = [x0, x2, x4, x6, x7, x5, x3, x1]
        assert_eq!(p.src(), &[0, 2, 4, 6, 7, 5, 3, 1]);
        let p1 = RealFftPlan::new(1);
        assert_eq!(p1.src(), &[0]);
        assert_eq!(p1.half().len(), 1);
    }

    #[test]
    #[should_panic]
    fn real_plan_rejects_non_power_of_two() {
        RealFftPlan::new(12);
    }

    #[test]
    fn hermitian_symmetry_for_real_input() {
        let mut rng = Pcg32::seeded(4);
        let n = 128;
        let p = FftPlan::new(n);
        let mut re = rng.normal_vec(n, 0.0, 1.0);
        let mut im = vec![0.0; n];
        p.forward(&mut re, &mut im);
        for k in 1..n / 2 {
            assert!((re[k] - re[n - k]).abs() < 1e-3);
            assert!((im[k] + im[n - k]).abs() < 1e-3);
        }
    }
}
