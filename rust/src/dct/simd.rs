//! Runtime-dispatched SIMD kernels for the SoA panel hot loops.
//!
//! The batched engine's inner loops all operate on [`LANES`] = 8
//! contiguous f32 lanes — exactly one 256-bit vector register. This
//! module provides two implementations of each panel kernel:
//!
//! * **scalar** — the portable fixed-8 loops (the mandatory fallback;
//!   LLVM auto-vectorizes them on most targets);
//! * **avx2** — explicit `std::arch` intrinsics (`x86_64` only), selected
//!   once per process behind an `is_x86_feature_detected!("avx2")` check.
//!
//! Both arms execute **identical arithmetic in identical order** (mul/add
//! only, never FMA), so their outputs are bit-identical — pinned by
//! `tests/property_realfft.rs`, which runs every kernel under both
//! dispatches. The active dispatch is resolved once by [`active`];
//! setting `ACDC_SIMD=scalar` (or `=avx2`) in the environment forces an
//! arm, which is how CI exercises the fallback on AVX2 hosts.
//!
//! Three kernels make up one fused `ACDC⁻¹` panel (see
//! [`crate::dct::batch`] for the surrounding data flow):
//!
//! 1. `fft_soa` — the radix-2 complex FFT over lane blocks, now run at
//!    **N/2** (the real-FFT Makhoul packing);
//! 2. `real_fwd` — un-twist of the half-size spectrum + DCT-II forward
//!    post-twiddle, with the ACDC `d`/`bias` optionally fused in;
//! 3. `real_inv` — DCT-III pre-twiddle + twist back down to the half
//!    spectrum fed to the inverse FFT.

use std::sync::OnceLock;

use super::batch::{lane, lane_mut, lane_pair, LANES};

/// Coefficient tables one real-FFT twist stage needs. `c_*` is the DCT
/// post-twiddle (`fw_*`, forward) or pre-twiddle (`bw_*`, inverse) of the
/// full size-N plan; `tw_*` is the full-size FFT twiddle table
/// e^{-2πik/N} for k in 0..N/2, which doubles as the Makhoul twist.
pub(crate) struct RealStage<'a> {
    /// Full (real) transform size N; the packed spectrum has N/2 bins.
    pub n: usize,
    /// DCT twiddle, real parts (length N).
    pub c_re: &'a [f32],
    /// DCT twiddle, imaginary parts (length N).
    pub c_im: &'a [f32],
    /// Twist twiddle e^{-2πik/N}, real parts (length N/2).
    pub tw_re: &'a [f32],
    /// Twist twiddle e^{-2πik/N}, imaginary parts (length N/2).
    pub tw_im: &'a [f32],
    /// Fused spectral diagonal (the ACDC `d`); `None` = ones.
    pub d: Option<&'a [f32]>,
    /// Fused spectral bias; `None` = zeros.
    pub bias: Option<&'a [f32]>,
}

impl<'a> RealStage<'a> {
    /// The fused diagonal/bias coefficients at bin `k` (1/0 when absent —
    /// `x*1 + 0` only canonicalizes `-0.0`, which no consumer observes).
    #[inline]
    fn coeff(&self, k: usize) -> (f32, f32) {
        (
            self.d.map_or(1.0, |d| d[k]),
            self.bias.map_or(0.0, |b| b[k]),
        )
    }
}

type FftSoaFn = fn(&mut [f32], &mut [f32], usize, &[u32], &[f32], &[f32], bool);
type RealFwdFn = fn(&RealStage, &[f32], &[f32], &mut [f32]);
type RealInvFn = fn(&RealStage, &[f32], &mut [f32], &mut [f32]);

/// One resolved kernel set (scalar or avx2). Obtain via [`active`],
/// [`scalar`] or [`avx2`]; the engine stores the reference it was built
/// with, so tests and benches can pin an arm explicitly.
pub struct Dispatch {
    name: &'static str,
    pub(crate) fft_soa: FftSoaFn,
    pub(crate) real_fwd: RealFwdFn,
    pub(crate) real_inv: RealInvFn,
}

impl Dispatch {
    /// The arm's name (`"scalar"` or `"avx2"`).
    pub fn name(&self) -> &'static str {
        self.name
    }
}

impl std::fmt::Debug for Dispatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Dispatch").field("name", &self.name).finish()
    }
}

static SCALAR: Dispatch = Dispatch {
    name: "scalar",
    fft_soa: scalar_fft_soa,
    real_fwd: scalar_real_fwd,
    real_inv: scalar_real_inv,
};

/// The portable kernel set — always available, and the reference the
/// SIMD arms must match bit for bit.
pub fn scalar() -> &'static Dispatch {
    &SCALAR
}

/// The AVX2 kernel set, when this host supports it (`None` elsewhere —
/// non-x86_64 builds compile only the scalar arm).
pub fn avx2() -> Option<&'static Dispatch> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Some(&x86::AVX2);
        }
    }
    None
}

/// The process-wide kernel set, resolved once: `ACDC_SIMD=scalar` forces
/// the portable arm, `ACDC_SIMD=avx2` requests AVX2 (falling back to
/// scalar if unavailable), anything else auto-detects.
pub fn active() -> &'static Dispatch {
    static ACTIVE: OnceLock<&'static Dispatch> = OnceLock::new();
    ACTIVE.get_or_init(|| match std::env::var("ACDC_SIMD").as_deref() {
        Ok("scalar") => scalar(),
        _ => avx2().unwrap_or_else(scalar),
    })
}

// ---------------------------------------------------------------------------
// Scalar arm (the portable reference)
// ---------------------------------------------------------------------------

/// Radix-2 complex FFT over SoA lane buffers: element `(k, l)` lives at
/// `k*LANES + l`. Identical schedule (bit-reversal + Danielson–Lanczos,
/// shared twiddle tables) to the scalar [`crate::dct::fft::FftPlan`],
/// with the butterfly applied to all [`LANES`] lanes per twiddle load.
/// The inverse includes the 1/n scaling, matching `FftPlan::inverse`.
fn scalar_fft_soa(
    re: &mut [f32],
    im: &mut [f32],
    n: usize,
    rev: &[u32],
    tw_re: &[f32],
    tw_im: &[f32],
    invert: bool,
) {
    debug_assert_eq!(re.len(), n * LANES);
    debug_assert_eq!(im.len(), n * LANES);
    if n == 1 {
        return;
    }
    fft_soa_bitrev(re, im, n, rev);
    let mut len = 2;
    while len <= n {
        let half = len / 2;
        let step = n / len;
        for start in (0..n).step_by(len) {
            let mut tidx = 0;
            for k in start..start + half {
                let wr = tw_re[tidx];
                let wi = if invert { -tw_im[tidx] } else { tw_im[tidx] };
                let m = k + half;
                // Disjoint lane blocks at k and m (k < m always).
                let (re_k, re_m) = lane_pair(re, k, m);
                let (im_k, im_m) = lane_pair(im, k, m);
                for l in 0..LANES {
                    let xr = re_m[l] * wr - im_m[l] * wi;
                    let xi = re_m[l] * wi + im_m[l] * wr;
                    re_m[l] = re_k[l] - xr;
                    im_m[l] = im_k[l] - xi;
                    re_k[l] += xr;
                    im_k[l] += xi;
                }
                tidx += step;
            }
        }
        len <<= 1;
    }
    if invert {
        fft_soa_scale(re, im, n);
    }
}

/// Bit-reversal reorder of whole lane blocks (shared by both arms — pure
/// swaps, bit-identical by construction).
fn fft_soa_bitrev(re: &mut [f32], im: &mut [f32], n: usize, rev: &[u32]) {
    for i in 0..n {
        let j = rev[i] as usize;
        if i < j {
            for l in 0..LANES {
                re.swap(i * LANES + l, j * LANES + l);
                im.swap(i * LANES + l, j * LANES + l);
            }
        }
    }
}

/// The inverse transform's 1/n scaling (shared by both arms).
fn fft_soa_scale(re: &mut [f32], im: &mut [f32], n: usize) {
    let inv = 1.0 / n as f32;
    for v in re.iter_mut() {
        *v *= inv;
    }
    for v in im.iter_mut() {
        *v *= inv;
    }
}

/// Forward un-twist + DCT-II post-twiddle (+ fused `d`/`bias`): from the
/// half-size spectrum lanes `Z` to the spectral-domain lanes
/// `out[k] = X[k]·d[k] + bias[k]` for k in 0..N.
///
/// Bin math (h = N/2, kk = h-k; Z[h] ≡ Z[0]):
/// `Ze = (Z[k]+conj(Z[kk]))/2`, `Zo = (Z[k]-conj(Z[kk]))/2i`,
/// `V[k] = Ze + tw[k]·Zo`, `X[k] = Re(fw[k]·V[k])`,
/// `X[N-k] = fw_re[N-k]·Vr + fw_im[N-k]·Vi` (Hermitian pickup).
fn scalar_real_fwd(st: &RealStage, zre: &[f32], zim: &[f32], out: &mut [f32]) {
    let n = st.n;
    let h = n / 2;
    debug_assert!(h >= 1);
    // k = 0 carries bins 0 and h: V[0] = ReZ0 + ImZ0, V[h] = ReZ0 - ImZ0.
    {
        let zr = lane(zre, 0);
        let zi = lane(zim, 0);
        let (f0, fh) = (st.c_re[0], st.c_re[h]);
        let (d0, b0) = st.coeff(0);
        let (dh, bh) = st.coeff(h);
        for l in 0..LANES {
            let v0 = zr[l] + zi[l];
            let vh = zr[l] - zi[l];
            out[l] = (f0 * v0) * d0 + b0;
            out[h * LANES + l] = (fh * vh) * dh + bh;
        }
    }
    for k in 1..h {
        let kk = h - k;
        let (twr, twi) = (st.tw_re[k], st.tw_im[k]);
        let (fkr, fki) = (st.c_re[k], st.c_im[k]);
        let (fnr, fni) = (st.c_re[n - k], st.c_im[n - k]);
        let (dk, bk) = st.coeff(k);
        let (dn, bn) = st.coeff(n - k);
        let zrk = lane(zre, k);
        let zik = lane(zim, k);
        let zrkk = lane(zre, kk);
        let zikk = lane(zim, kk);
        // Two disjoint output lane blocks (k < h < n-k for k in 1..h).
        let (out_k, out_nk) = lane_pair(out, k, n - k);
        for l in 0..LANES {
            let zer = 0.5 * (zrk[l] + zrkk[l]);
            let zei = 0.5 * (zik[l] - zikk[l]);
            let zor = 0.5 * (zik[l] + zikk[l]);
            let zoi = -0.5 * (zrk[l] - zrkk[l]);
            let vr = zer + (twr * zor - twi * zoi);
            let vi = zei + (twr * zoi + twi * zor);
            out_k[l] = (fkr * vr - fki * vi) * dk + bk;
            out_nk[l] = (fnr * vr + fni * vi) * dn + bn;
        }
    }
}

/// Inverse pre-twiddle + twist down: from spectral lanes `x` (bins 0..N)
/// to the half-size spectrum lanes `Z` fed to the inverse FFT.
///
/// Bin math (hk = h-k in 1..=h; x[N] ≡ 0):
/// `V[j] = bw[j]·(x[j] - i·x[N-j])`,
/// `Ze = (V[k]+conj(V[hk]))/2`, `D = (V[k]-conj(V[hk]))/2`,
/// `Zo = conj(tw[k])·D`, `Z[k] = Ze + i·Zo`.
fn scalar_real_inv(st: &RealStage, x: &[f32], zre: &mut [f32], zim: &mut [f32]) {
    let n = st.n;
    let h = n / 2;
    debug_assert!(h >= 1);
    for k in 0..h {
        let hk = h - k; // 1..=h — never 0, so x[n - hk] is always in range
        let (ckr, cki) = (st.c_re[k], st.c_im[k]);
        let (chr, chi) = (st.c_re[hk], st.c_im[hk]);
        let (twr, twi) = (st.tw_re[k], st.tw_im[k]);
        let xk = lane(x, k);
        let xhk = lane(x, hk);
        let xnhk = lane(x, n - hk);
        let zr = lane_mut(zre, k);
        // k = 0 has no x[n-k] partner (x[N] ≡ 0 in Makhoul's recurrence).
        if k == 0 {
            let zi = lane_mut(zim, 0);
            for l in 0..LANES {
                let vrk = ckr * xk[l];
                let vik = cki * xk[l];
                let vrh = chr * xhk[l] + chi * xnhk[l];
                let vih = chi * xhk[l] - chr * xnhk[l];
                let zer = 0.5 * (vrk + vrh);
                let zei = 0.5 * (vik - vih);
                let dr = 0.5 * (vrk - vrh);
                let di = 0.5 * (vik + vih);
                let zor = twr * dr + twi * di;
                let zoi = twr * di - twi * dr;
                zr[l] = zer - zoi;
                zi[l] = zei + zor;
            }
            continue;
        }
        let xnk = lane(x, n - k);
        let zi = lane_mut(zim, k);
        for l in 0..LANES {
            let vrk = ckr * xk[l] + cki * xnk[l];
            let vik = cki * xk[l] - ckr * xnk[l];
            let vrh = chr * xhk[l] + chi * xnhk[l];
            let vih = chi * xhk[l] - chr * xnhk[l];
            let zer = 0.5 * (vrk + vrh);
            let zei = 0.5 * (vik - vih);
            let dr = 0.5 * (vrk - vrh);
            let di = 0.5 * (vik + vih);
            let zor = twr * dr + twi * di;
            let zoi = twr * di - twi * dr;
            zr[l] = zer - zoi;
            zi[l] = zei + zor;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 arm (x86_64 only) — identical op order, one __m256 per lane block
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::*;
    use std::arch::x86_64::*;

    pub(super) static AVX2: Dispatch = Dispatch {
        name: "avx2",
        fft_soa,
        real_fwd,
        real_inv,
    };

    /// Load one 8-lane block. Unaligned load: `Vec<f32>` only guarantees
    /// 4-byte alignment.
    #[inline]
    unsafe fn ld(b: &[f32; LANES]) -> __m256 {
        _mm256_loadu_ps(b.as_ptr())
    }

    #[inline]
    unsafe fn st_(b: &mut [f32; LANES], v: __m256) {
        _mm256_storeu_ps(b.as_mut_ptr(), v)
    }

    // Safe wrappers: only reachable through `avx2()`, which gates on
    // `is_x86_feature_detected!("avx2")`, so the target-feature calls are
    // sound on every path that can obtain this Dispatch.

    fn fft_soa(
        re: &mut [f32],
        im: &mut [f32],
        n: usize,
        rev: &[u32],
        tw_re: &[f32],
        tw_im: &[f32],
        invert: bool,
    ) {
        unsafe { fft_soa_avx2(re, im, n, rev, tw_re, tw_im, invert) }
    }

    fn real_fwd(stg: &RealStage, zre: &[f32], zim: &[f32], out: &mut [f32]) {
        unsafe { real_fwd_avx2(stg, zre, zim, out) }
    }

    fn real_inv(stg: &RealStage, x: &[f32], zre: &mut [f32], zim: &mut [f32]) {
        unsafe { real_inv_avx2(stg, x, zre, zim) }
    }

    /// [`super::scalar_fft_soa`] with the 8-lane butterfly in explicit
    /// AVX2 (mul/add/sub only — no FMA, so rounding matches scalar).
    #[target_feature(enable = "avx2")]
    unsafe fn fft_soa_avx2(
        re: &mut [f32],
        im: &mut [f32],
        n: usize,
        rev: &[u32],
        tw_re: &[f32],
        tw_im: &[f32],
        invert: bool,
    ) {
        debug_assert_eq!(re.len(), n * LANES);
        debug_assert_eq!(im.len(), n * LANES);
        if n == 1 {
            return;
        }
        fft_soa_bitrev(re, im, n, rev);
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            for start in (0..n).step_by(len) {
                let mut tidx = 0;
                for k in start..start + half {
                    let wr = _mm256_set1_ps(tw_re[tidx]);
                    let wi = _mm256_set1_ps(if invert { -tw_im[tidx] } else { tw_im[tidx] });
                    let m = k + half;
                    let (re_k, re_m) = lane_pair(re, k, m);
                    let (im_k, im_m) = lane_pair(im, k, m);
                    let rm = ld(re_m);
                    let imm = ld(im_m);
                    let rk = ld(re_k);
                    let imk = ld(im_k);
                    // xr = rm*wr - imm*wi; xi = rm*wi + imm*wr
                    let xr = _mm256_sub_ps(_mm256_mul_ps(rm, wr), _mm256_mul_ps(imm, wi));
                    let xi = _mm256_add_ps(_mm256_mul_ps(rm, wi), _mm256_mul_ps(imm, wr));
                    st_(re_m, _mm256_sub_ps(rk, xr));
                    st_(im_m, _mm256_sub_ps(imk, xi));
                    st_(re_k, _mm256_add_ps(rk, xr));
                    st_(im_k, _mm256_add_ps(imk, xi));
                    tidx += step;
                }
            }
            len <<= 1;
        }
        if invert {
            fft_soa_scale(re, im, n);
        }
    }

    /// [`super::scalar_real_fwd`] in AVX2 (same op order).
    #[target_feature(enable = "avx2")]
    unsafe fn real_fwd_avx2(stg: &RealStage, zre: &[f32], zim: &[f32], out: &mut [f32]) {
        let n = stg.n;
        let h = n / 2;
        let half_ = _mm256_set1_ps(0.5);
        let neg_half = _mm256_set1_ps(-0.5);
        {
            let zr = ld(lane(zre, 0));
            let zi = ld(lane(zim, 0));
            let v0 = _mm256_add_ps(zr, zi);
            let vh = _mm256_sub_ps(zr, zi);
            let (d0, b0) = stg.coeff(0);
            let (dh, bh) = stg.coeff(h);
            let x0 = _mm256_mul_ps(_mm256_set1_ps(stg.c_re[0]), v0);
            let xh = _mm256_mul_ps(_mm256_set1_ps(stg.c_re[h]), vh);
            let o0 = _mm256_add_ps(_mm256_mul_ps(x0, _mm256_set1_ps(d0)), _mm256_set1_ps(b0));
            let oh = _mm256_add_ps(_mm256_mul_ps(xh, _mm256_set1_ps(dh)), _mm256_set1_ps(bh));
            st_(lane_mut(out, 0), o0);
            st_(lane_mut(out, h), oh);
        }
        for k in 1..h {
            let kk = h - k;
            let twr = _mm256_set1_ps(stg.tw_re[k]);
            let twi = _mm256_set1_ps(stg.tw_im[k]);
            let fkr = _mm256_set1_ps(stg.c_re[k]);
            let fki = _mm256_set1_ps(stg.c_im[k]);
            let fnr = _mm256_set1_ps(stg.c_re[n - k]);
            let fni = _mm256_set1_ps(stg.c_im[n - k]);
            let (dk, bk) = stg.coeff(k);
            let (dn, bn) = stg.coeff(n - k);
            let zrk = ld(lane(zre, k));
            let zik = ld(lane(zim, k));
            let zrkk = ld(lane(zre, kk));
            let zikk = ld(lane(zim, kk));
            let zer = _mm256_mul_ps(half_, _mm256_add_ps(zrk, zrkk));
            let zei = _mm256_mul_ps(half_, _mm256_sub_ps(zik, zikk));
            let zor = _mm256_mul_ps(half_, _mm256_add_ps(zik, zikk));
            let zoi = _mm256_mul_ps(neg_half, _mm256_sub_ps(zrk, zrkk));
            let vr = _mm256_add_ps(
                zer,
                _mm256_sub_ps(_mm256_mul_ps(twr, zor), _mm256_mul_ps(twi, zoi)),
            );
            let vi = _mm256_add_ps(
                zei,
                _mm256_add_ps(_mm256_mul_ps(twr, zoi), _mm256_mul_ps(twi, zor)),
            );
            let xk = _mm256_sub_ps(_mm256_mul_ps(fkr, vr), _mm256_mul_ps(fki, vi));
            let xnk = _mm256_add_ps(_mm256_mul_ps(fnr, vr), _mm256_mul_ps(fni, vi));
            let ok = _mm256_add_ps(_mm256_mul_ps(xk, _mm256_set1_ps(dk)), _mm256_set1_ps(bk));
            let onk = _mm256_add_ps(_mm256_mul_ps(xnk, _mm256_set1_ps(dn)), _mm256_set1_ps(bn));
            let (out_k, out_nk) = lane_pair(out, k, n - k);
            st_(out_k, ok);
            st_(out_nk, onk);
        }
    }

    /// [`super::scalar_real_inv`] in AVX2 (same op order).
    #[target_feature(enable = "avx2")]
    unsafe fn real_inv_avx2(stg: &RealStage, x: &[f32], zre: &mut [f32], zim: &mut [f32]) {
        let n = stg.n;
        let h = n / 2;
        let half_ = _mm256_set1_ps(0.5);
        for k in 0..h {
            let hk = h - k;
            let ckr = _mm256_set1_ps(stg.c_re[k]);
            let cki = _mm256_set1_ps(stg.c_im[k]);
            let chr = _mm256_set1_ps(stg.c_re[hk]);
            let chi = _mm256_set1_ps(stg.c_im[hk]);
            let twr = _mm256_set1_ps(stg.tw_re[k]);
            let twi = _mm256_set1_ps(stg.tw_im[k]);
            let xk = ld(lane(x, k));
            let xhk = ld(lane(x, hk));
            let xnhk = ld(lane(x, n - hk));
            let (vrk, vik) = if k == 0 {
                (_mm256_mul_ps(ckr, xk), _mm256_mul_ps(cki, xk))
            } else {
                let xnk = ld(lane(x, n - k));
                (
                    _mm256_add_ps(_mm256_mul_ps(ckr, xk), _mm256_mul_ps(cki, xnk)),
                    _mm256_sub_ps(_mm256_mul_ps(cki, xk), _mm256_mul_ps(ckr, xnk)),
                )
            };
            let vrh = _mm256_add_ps(_mm256_mul_ps(chr, xhk), _mm256_mul_ps(chi, xnhk));
            let vih = _mm256_sub_ps(_mm256_mul_ps(chi, xhk), _mm256_mul_ps(chr, xnhk));
            let zer = _mm256_mul_ps(half_, _mm256_add_ps(vrk, vrh));
            let zei = _mm256_mul_ps(half_, _mm256_sub_ps(vik, vih));
            let dr = _mm256_mul_ps(half_, _mm256_sub_ps(vrk, vrh));
            let di = _mm256_mul_ps(half_, _mm256_add_ps(vik, vih));
            let zor = _mm256_add_ps(_mm256_mul_ps(twr, dr), _mm256_mul_ps(twi, di));
            let zoi = _mm256_sub_ps(_mm256_mul_ps(twr, di), _mm256_mul_ps(twi, dr));
            st_(lane_mut(zre, k), _mm256_sub_ps(zer, zoi));
            st_(lane_mut(zim, k), _mm256_add_ps(zei, zor));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_dispatch_always_available() {
        assert_eq!(scalar().name(), "scalar");
    }

    #[test]
    fn active_dispatch_is_scalar_or_avx2() {
        let d = active();
        assert!(d.name() == "scalar" || d.name() == "avx2", "{}", d.name());
        // The env override is resolved once; forcing scalar must always
        // be possible on any host.
        assert!(std::ptr::eq(scalar(), scalar()));
    }

    #[test]
    fn avx2_reports_consistently_with_detection() {
        #[cfg(target_arch = "x86_64")]
        {
            let detected = std::arch::is_x86_feature_detected!("avx2");
            assert_eq!(avx2().is_some(), detected);
            if let Some(d) = avx2() {
                assert_eq!(d.name(), "avx2");
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        assert!(avx2().is_none());
    }
}
