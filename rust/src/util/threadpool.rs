//! Fixed-size worker thread pool (tokio is not in the offline registry).
//!
//! The coordinator and the bench harness need: (a) a pool that executes
//! boxed jobs, (b) scoped fork-join parallelism for data-parallel loops
//! (used by the fused ACDC reference implementation at large batch sizes).
//! Built on `std::thread` + channels only.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed closures.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("acdc-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            guard.recv()
                        };
                        match msg {
                            Ok(Msg::Run(job)) => {
                                // A panicking job must not kill the worker:
                                // the pool is process-wide (`global()`) and a
                                // dead worker would silently shrink serving
                                // capacity for the rest of the process. The
                                // panic still surfaces to `map` callers via
                                // the dropped result sender.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx,
            handles,
            size,
            queued,
        }
    }

    /// Pool sized to the machine's parallelism.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n)
    }

    /// Number of worker threads in the pool.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Fire-and-forget execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Msg::Run(Box::new(f)))
            .expect("pool channel closed");
    }

    /// Run `f(i)` for i in 0..n, blocking until all complete, returning
    /// results in order. Panics in jobs are propagated.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, T)>, Receiver<(usize, T)>) = channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = f(i);
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut received = 0;
        while received < n {
            match rrx.recv() {
                Ok((i, v)) => {
                    slots[i] = Some(v);
                    received += 1;
                }
                Err(_) => panic!("worker panicked during ThreadPool::map"),
            }
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Process-wide shared pool for data-parallel kernels (lazily spawned at
/// the machine's parallelism). Used by the batched ACDC engine's panel
/// fan-out ([`crate::dct::batch`]) and the native serving executors, so
/// concurrent batches share one fixed set of compute threads instead of
/// spawning per call.
pub fn global() -> &'static ThreadPool {
    static POOL: std::sync::OnceLock<ThreadPool> = std::sync::OnceLock::new();
    POOL.get_or_init(ThreadPool::with_default_size)
}

/// Split `0..len` into at most `parts` contiguous ranges of near-equal size.
pub fn split_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 {
        return vec![];
    }
    let parts = parts.clamp(1, len);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_returns_in_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn split_ranges_covers_exactly() {
        for (len, parts) in [(10, 3), (7, 7), (5, 9), (100, 4), (1, 1)] {
            let rs = split_ranges(len, parts);
            assert!(rs.len() <= parts);
            let mut expect = 0;
            for r in &rs {
                assert_eq!(r.start, expect);
                assert!(!r.is_empty());
                expect = r.end;
            }
            assert_eq!(expect, len);
        }
    }

    #[test]
    fn split_ranges_empty_len() {
        assert!(split_ranges(0, 4).is_empty());
    }

    #[test]
    fn panicking_job_does_not_kill_workers() {
        let pool = ThreadPool::new(1); // single worker: a dead one would wedge
        pool.execute(|| panic!("boom"));
        // The same worker must still drain subsequent jobs.
        let out = pool.map(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn map_propagates_job_panic_without_wedging() {
        let pool = ThreadPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(4, |i| {
                assert!(i != 2, "induced failure");
                i
            })
        }));
        assert!(res.is_err(), "map must surface the job panic");
        // And the pool stays usable afterwards.
        assert_eq!(pool.map(2, |i| i), vec![0, 1]);
    }

    #[test]
    fn global_pool_is_shared_and_works() {
        let p1 = global();
        let p2 = global();
        assert!(std::ptr::eq(p1, p2));
        assert_eq!(p1.map(4, |i| i * 2), vec![0, 2, 4, 6]);
    }

    #[test]
    fn nested_map_does_not_deadlock() {
        // map() jobs must not block on pool capacity for completion of
        // *other* jobs, only their own — verify a 1-thread pool drains a
        // sequential map.
        let pool = ThreadPool::new(1);
        let out = pool.map(10, |i| i + 1);
        assert_eq!(out.len(), 10);
    }
}
