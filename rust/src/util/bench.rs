//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Provides warmup + timed iterations with robust statistics (median, MAD,
//! p10/p90), black-box value sinks, and a paper-style table printer used by
//! every `cargo bench` target to regenerate the paper's figures as text
//! series.

use std::time::{Duration, Instant};

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Case label.
    pub name: String,
    /// Total iterations measured.
    pub iters: usize,
    /// Median per-iteration time, nanoseconds.
    pub median_ns: f64,
    /// Mean per-iteration time, nanoseconds.
    pub mean_ns: f64,
    /// 10th-percentile per-iteration time, nanoseconds.
    pub p10_ns: f64,
    /// 90th-percentile per-iteration time, nanoseconds.
    pub p90_ns: f64,
    /// Median absolute deviation, nanoseconds.
    pub mad_ns: f64,
}

impl Measurement {
    /// Median per-iteration time as a `Duration`.
    pub fn median(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }

    /// Throughput in "units processed per second" for a per-iteration unit
    /// count (e.g. rows in a batch, bytes moved).
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / (self.median_ns * 1e-9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Warmup/calibration window before measuring.
    pub warmup: Duration,
    /// Measurement window.
    pub measure: Duration,
    /// Minimum timed samples regardless of window.
    pub min_iters: usize,
    /// Hard cap on total iterations.
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }
}

impl Bench {
    /// Quick preset for expensive end-to-end cases.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 100_000,
        }
    }

    /// Time `f` repeatedly; returns robust statistics over per-iter times.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup + calibration.
        let wstart = Instant::now();
        let mut warm_iters = 0usize;
        while wstart.elapsed() < self.warmup || warm_iters < 1 {
            f();
            warm_iters += 1;
        }
        let per_iter = wstart.elapsed().as_secs_f64() / warm_iters as f64;
        // Batch so each sample is ≥ ~20µs (timer noise floor).
        let batch = ((20e-6 / per_iter).ceil() as usize).clamp(1, 1 << 20);

        let mut samples: Vec<f64> = Vec::new();
        let mstart = Instant::now();
        let mut total_iters = 0usize;
        while (mstart.elapsed() < self.measure || samples.len() < self.min_iters)
            && total_iters < self.max_iters
        {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile(&samples, 50.0);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let mad = {
            let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
            devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            percentile(&devs, 50.0)
        };
        Measurement {
            name: name.to_string(),
            iters: total_iters,
            median_ns: median,
            mean_ns: mean,
            p10_ns: percentile(&samples, 10.0),
            p90_ns: percentile(&samples, 90.0),
            mad_ns: mad,
        }
    }
}

/// Percentile of a pre-sorted slice (linear interpolation).
pub fn percentile(sorted: &[f64], pct: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Render nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Fixed-width table printer for paper-style series output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render with right-aligned, width-fitted columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_sane_stats() {
        let b = Bench {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            min_iters: 3,
            max_iters: 1_000_000,
        };
        let mut acc = 0u64;
        let m = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.median_ns > 0.0);
        assert!(m.p10_ns <= m.median_ns && m.median_ns <= m.p90_ns);
        assert!(m.iters >= 3);
    }

    #[test]
    fn measures_a_known_sleep_roughly() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(60),
            min_iters: 3,
            max_iters: 200,
        };
        let m = b.run("sleep", || std::thread::sleep(Duration::from_millis(2)));
        assert!(m.median_ns > 1.5e6, "median={}", m.median_ns);
        assert!(m.median_ns < 20e6, "median={}", m.median_ns);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0, 20.0, 30.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 30.0);
        assert_eq!(percentile(&v, 50.0), 15.0);
    }

    #[test]
    fn percentile_edge_cases() {
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[7.0], 90.0), 7.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["n", "time"]);
        t.row(vec!["128".into(), "1.2ms".into()]);
        t.row(vec!["16384".into(), "0.9ms".into()]);
        let r = t.render();
        assert!(r.contains("16384"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn throughput_math() {
        let m = Measurement {
            name: "t".into(),
            iters: 1,
            median_ns: 1e6, // 1ms
            mean_ns: 1e6,
            p10_ns: 1e6,
            p90_ns: 1e6,
            mad_ns: 0.0,
        };
        let per_sec = m.throughput(128.0);
        assert!((per_sec - 128_000.0).abs() < 1.0);
    }
}
