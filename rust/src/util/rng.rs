//! Deterministic pseudo-random numbers (PCG32) — no external crates.
//!
//! The offline registry carries only the `xla` closure, so randomness is
//! implemented from scratch: a PCG-XSH-RR 32-bit generator (O'Neill 2014)
//! with helpers for uniforms, Box–Muller Gaussians and Fisher–Yates
//! permutations. Deterministic in the seed, which every experiment harness
//! relies on for reproducibility.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second output of the last Box–Muller draw.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
            spare_normal: None,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in [0, bound) via Lemire-style rejection.
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean / stddev.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Vector of f32 normals.
    pub fn normal_vec(&mut self, len: usize, mean: f64, std: f64) -> Vec<f32> {
        (0..len).map(|_| self.normal_with(mean, std) as f32).collect()
    }

    /// Vector of f32 uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..len).map(|_| self.uniform_in(lo, hi) as f32).collect()
    }

    /// Fisher–Yates permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u32 + 1) as usize;
            p.swap(i, j);
        }
        p
    }

    /// Random ±1 signs (for Fastfood's binary diagonal).
    pub fn sign_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| if self.next_u32() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new(1, 10);
        let mut b = Pcg32::new(1, 11);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg32::seeded(4);
        let mean: f64 = (0..50_000).map(|_| r.uniform()).sum::<f64>() / 50_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn normal_with_shifts_and_scales() {
        let mut r = Pcg32::seeded(6);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal_with(1.0, 0.1)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 1.0).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Pcg32::seeded(8);
        for n in [1usize, 2, 7, 64, 255] {
            let mut p = r.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n as u32).collect::<Vec<_>>());
        }
    }

    #[test]
    fn permutation_not_identity_for_large_n() {
        let mut r = Pcg32::seeded(9);
        let p = r.permutation(256);
        assert!(p.iter().enumerate().any(|(i, &v)| i as u32 != v));
    }

    #[test]
    fn sign_vec_balanced() {
        let mut r = Pcg32::seeded(10);
        let s = r.sign_vec(10_000);
        let pos = s.iter().filter(|&&v| v > 0.0).count();
        assert!((pos as f64 - 5000.0).abs() < 300.0);
        assert!(s.iter().all(|&v| v == 1.0 || v == -1.0));
    }
}
