//! Minimal JSON parser + writer (serde is not in the offline registry).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used to read `artifacts/manifest.json` and to
//! write experiment reports. Not performance critical.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys for stable serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The boolean payload, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric payload, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to i64, if this is a `Num`.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// The number as usize if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// The string payload, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key → value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Parse a JSON document. Nesting is capped at [`MAX_DEPTH`] so
    /// adversarial input (the gateway feeds this untrusted bodies)
    /// cannot overflow the stack.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

/// Compact serialization (`Json::to_string()` via the `ToString` blanket).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Infinity; emit null (JSON.stringify behaviour)
        // so serialized documents always reparse.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset of the error in the input.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    /// Bump the container depth, rejecting pathological nesting.
    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => {
                self.descend()?;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.descend()?;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            // Duplicate keys are a classic smuggling vector (different
            // consumers disagree on which value wins); refuse outright.
            if map.insert(key.clone(), val).is_some() {
                return Err(self.err(&format!("duplicate key '{key}'")));
            }
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            // A non-low-surrogate here must error; the
                            // subtraction below would underflow on it.
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("expected low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut digits = 0usize;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
            digits += 1;
        }
        if digits == 0 {
            return Err(self.err("expected digits"));
        }
        // The JSON grammar requires digits after '.' and in exponents;
        // Rust's f64 parser is laxer ("1.", "1.e5"), so enforce here.
        if self.peek() == Some(b'.') {
            self.pos += 1;
            let mut frac = 0usize;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
                frac += 1;
            }
            if frac == 0 {
                return Err(self.err("expected digits after '.'"));
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            let mut exp = 0usize;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
                exp += 1;
            }
            if exp == 0 {
                return Err(self.err("expected digits in exponent"));
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n = text
            .parse::<f64>()
            .map_err(|_| self.err("invalid number"))?;
        // Overflowing literals ("1e999") parse to ±inf; JSON has no
        // non-finite numbers, so reject rather than smuggle an inf in.
        if !n.is_finite() {
            return Err(self.err("number out of range"));
        }
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Deepest container nesting [`Json::parse`] accepts. Recursive descent
/// uses one stack frame per level; 128 levels is far beyond any real
/// payload while keeping worst-case stack use trivially small.
pub const MAX_DEPTH: usize = 128;

/// Convenience builder for object literals in report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("d"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" \\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" \\ A"));
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn deep_nesting_is_rejected_not_stack_overflow() {
        // The gateway feeds untrusted bodies to this parser; a ~40 KB
        // bracket bomb must yield a parse error, not a process abort.
        let bomb = "[".repeat(50_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        // At the limit, parsing still succeeds.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&over).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn rejects_overflowing_numbers() {
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        // Large-but-finite stays accepted.
        assert_eq!(Json::parse("1e308").unwrap().as_f64(), Some(1e308));
    }

    #[test]
    fn rejects_duplicate_object_keys() {
        let err = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap_err();
        assert!(err.msg.contains("duplicate"), "{err}");
        // Same key at different nesting levels is fine.
        assert!(Json::parse(r#"{"a": {"a": 1}}"#).is_ok());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let arr = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NEG_INFINITY)]);
        assert_eq!(Json::parse(&arr.to_string()).unwrap(), Json::parse("[1,null]").unwrap());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true},"s":"v"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":"d"}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn as_usize_rejects_negatives_and_fractions() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(-3.0).as_usize(), None);
        assert_eq!(Json::Num(3.5).as_usize(), None);
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("k", Json::Num(1.0))]);
        assert_eq!(v.get("k").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": 1,
          "artifacts": [
            {"name": "x", "file": "x.hlo.txt",
             "inputs": [{"name": "a", "shape": [4, 64], "dtype": "f32"}],
             "outputs": [{"name": "y", "shape": [], "dtype": "f32"}],
             "tags": {"experiment": "quickstart", "n": 64}}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        let a0 = &arts[0];
        assert_eq!(a0.get("name").unwrap().as_str(), Some("x"));
        let shape = a0.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.iter().map(|s| s.as_usize().unwrap()).collect::<Vec<_>>(), vec![4, 64]);
    }
}
