//! From-scratch substrates: JSON, CLI, RNG, thread pool, bench harness.
//!
//! The offline crate registry ships only the `xla` closure, so the support
//! libraries a project of this shape would normally pull in (serde, clap,
//! rand, tokio, criterion) are implemented here, sized to what the system
//! actually needs (DESIGN.md substitution S5).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod threadpool;

/// Format a parameter count like the paper's Table 1 ("58.7M", "165,888").
pub fn fmt_params(n: u64) -> String {
    if n >= 10_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else {
        // thousands separators
        let s = n.to_string();
        let mut out = String::new();
        for (i, c) in s.chars().enumerate() {
            if i > 0 && (s.len() - i) % 3 == 0 {
                out.push(',');
            }
            out.push(c);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_params_bands() {
        assert_eq!(fmt_params(512), "512");
        assert_eq!(fmt_params(9_216), "9,216");
        assert_eq!(fmt_params(165_888), "165,888");
        assert_eq!(fmt_params(1_500_000), "1.50M");
        assert_eq!(fmt_params(58_700_000), "58.7M");
    }
}
