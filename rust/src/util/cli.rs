//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and auto-generated usage text. Each binary declares
//! its options up front so `--help` is accurate.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option (for usage text and validation).
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name (without the leading `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Default value applied when the option is absent.
    pub default: Option<&'static str>,
    /// True for boolean flags (no value).
    pub is_flag: bool,
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Declare options, then parse `std::env::args()`.
    pub fn parse(specs: Vec<OptSpec>) -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse_from(&argv, specs)
    }

    /// Parse an explicit argv (first element = program name).
    pub fn parse_from(argv: &[String], specs: Vec<OptSpec>) -> Result<Args, String> {
        let mut args = Args {
            program: argv.first().cloned().unwrap_or_default(),
            specs,
            ..Default::default()
        };
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(args.usage());
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = args
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", args.usage()))?
                    .clone();
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Usage text from the declared specs.
    pub fn usage(&self) -> String {
        let mut s = format!("usage: {} [options] [args]\n\noptions:\n", self.program);
        for spec in &self.specs {
            let mut left = format!("  --{}", spec.name);
            if !spec.is_flag {
                left.push_str(" <value>");
            }
            let _ = write!(s, "{left:<28} {}", spec.help);
            if let Some(d) = spec.default {
                let _ = write!(s, " (default: {d})");
            }
            s.push('\n');
        }
        s
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw option value (falling back to the declared default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str()).or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .and_then(|s| s.default)
        })
    }

    /// Owned option value (falling back to the declared default).
    pub fn get_string(&self, name: &str) -> Option<String> {
        self.get(name).map(|s| s.to_string())
    }

    /// Option value parsed as usize.
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| format!("--{name}: expected integer, got '{v}'")))
            .transpose()
    }

    /// Option value parsed as f64.
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| format!("--{name}: expected number, got '{v}'")))
            .transpose()
    }

    /// Comma-separated list of usize, e.g. `--sizes 128,256,512`.
    pub fn get_usize_list(&self, name: &str) -> Result<Option<Vec<usize>>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .map_err(|_| format!("--{name}: bad element '{t}'"))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Positional (non-option) arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Shorthand for building an option spec.
pub fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec {
        name,
        help,
        default,
        is_flag: false,
    }
}

/// Shorthand for building a boolean flag spec.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(parts: &[&str]) -> Vec<String> {
        std::iter::once("prog")
            .chain(parts.iter().copied())
            .map(String::from)
            .collect()
    }

    fn specs() -> Vec<OptSpec> {
        vec![
            opt("steps", "number of steps", Some("100")),
            opt("lr", "learning rate", Some("0.1")),
            opt("sizes", "comma list", None),
            flag("verbose", "chatty output"),
        ]
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse_from(&argv(&["--steps", "5", "--lr=0.5"]), specs()).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), Some(5));
        assert_eq!(a.get_f64("lr").unwrap(), Some(0.5));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from(&argv(&[]), specs()).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), Some(100));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn flags_and_positional() {
        let a = Args::parse_from(&argv(&["--verbose", "file.txt"]), specs()).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["file.txt".to_string()]);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(Args::parse_from(&argv(&["--nope"]), specs()).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse_from(&argv(&["--steps"]), specs()).is_err());
    }

    #[test]
    fn flag_with_value_is_error() {
        assert!(Args::parse_from(&argv(&["--verbose=1"]), specs()).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse_from(&argv(&["--steps", "abc"]), specs()).unwrap();
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn usize_list() {
        let a = Args::parse_from(&argv(&["--sizes", "128, 256,512"]), specs()).unwrap();
        assert_eq!(a.get_usize_list("sizes").unwrap(), Some(vec![128, 256, 512]));
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = Args::parse_from(&argv(&["--help"]), specs()).unwrap_err();
        assert!(e.contains("--steps"));
        assert!(e.contains("default: 100"));
    }
}
