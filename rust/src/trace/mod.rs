//! Per-request pipeline tracing: trace IDs, fixed-slot span records, and
//! a bounded lock-free slow-request ring.
//!
//! The serving pipeline spans gateway → admission → batcher → worker →
//! engine → serializer; end-to-end percentiles alone cannot say *where* a
//! p99 request spent its time. This module provides the pieces the
//! gateway threads through that path:
//!
//! * [`mint_trace_id`] — an allocation-free 64-bit trace ID minted at
//!   admission, echoed back as the `x-trace-id` response header and
//!   attached to every structured log event ([`log`]);
//! * [`SpanRecord`] — a fixed-size per-request record with one nanosecond
//!   slot per [`Stage`]. It lives inside the per-connection arena, so
//!   tracing being on by default costs **zero heap allocations** per
//!   request (the PR-5 invariant);
//! * [`SlowRing`] — a bounded, lock-free ring of the most recent requests
//!   whose total latency crossed the configured threshold, served by
//!   `GET /v1/debug/slow` and followed by `acdc tail`.
//!
//! Everything here is dependency-free and built on word-sized atomics:
//! the ring is a per-slot seqlock over `AtomicU64` words, so readers
//! never block writers and a torn snapshot is detected and skipped, not
//! returned.

pub mod log;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

/// One measured pipeline stage, in request order.
///
/// The gateway stamps `Parse`/`Admission`/`Serialize`/`Write` on the
/// connection thread; `QueueWait`/`BatchForm`/`Execute` are measured by
/// the batcher/worker and travel back on the response slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// JSON feature parsing of the request body.
    Parse,
    /// Admission control: drain gate, in-flight cap, token bucket.
    Admission,
    /// Upstream proxy exchange on the router role (connect + write +
    /// wait + read across retries/hedges); zero on shard gateways.
    Upstream,
    /// Enqueue until the batcher formed a batch containing the request.
    QueueWait,
    /// Batch handoff: formation until the worker starts executing
    /// (channel transit plus input gather/padding).
    BatchForm,
    /// Executor call (the SELL transform itself).
    Execute,
    /// Response-body serialization into the retained write buffer.
    Serialize,
    /// Socket write of head + body.
    Write,
}

impl Stage {
    /// Number of stages (the span record's slot count).
    pub const COUNT: usize = 8;

    /// All stages in pipeline order.
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Parse,
        Stage::Admission,
        Stage::Upstream,
        Stage::QueueWait,
        Stage::BatchForm,
        Stage::Execute,
        Stage::Serialize,
        Stage::Write,
    ];

    /// Slot index of this stage.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable snake_case name used in metrics, JSON, and logs.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Admission => "admission",
            Stage::Upstream => "upstream",
            Stage::QueueWait => "queue_wait",
            Stage::BatchForm => "batch_form",
            Stage::Execute => "execute",
            Stage::Serialize => "serialize",
            Stage::Write => "write",
        }
    }
}

/// Fixed-size per-request span record: one nanosecond slot per [`Stage`]
/// plus identity and outcome. `Copy` and word-packable so it can live in
/// the connection arena and be published through the lock-free ring
/// without ever touching the heap.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpanRecord {
    /// Trace ID minted at admission (0 = unset / untraced request).
    pub trace_id: u64,
    /// Per-stage latency in nanoseconds, indexed by [`Stage::index`].
    pub stage_ns: [u64; Stage::COUNT],
    /// End-to-end latency (request read complete → response flushed).
    pub total_ns: u64,
    /// Wall-clock capture time in Unix milliseconds (set when the record
    /// is published to the slow ring).
    pub unix_ms: u64,
    /// HTTP status the request was answered with.
    pub status: u16,
    /// Feature rows in the request.
    pub rows: u32,
    /// Executed batch bucket the request rode in (max across rows).
    pub batch: u32,
}

/// Packed width of a [`SpanRecord`] in `u64` words (ring slot size).
const WORDS: usize = Stage::COUNT + 4;

impl SpanRecord {
    /// Clear every field (the arena reuses one record per connection).
    pub fn reset(&mut self) {
        *self = SpanRecord::default();
    }

    /// Store a stage duration.
    pub fn set(&mut self, stage: Stage, d: Duration) {
        self.stage_ns[stage.index()] = d.as_nanos() as u64;
    }

    /// Stage duration in nanoseconds.
    pub fn get(&self, stage: Stage) -> u64 {
        self.stage_ns[stage.index()]
    }

    /// The stage that consumed the most time (ties: earliest wins).
    pub fn slowest(&self) -> Stage {
        let mut best = Stage::ALL[0];
        for s in Stage::ALL {
            if self.stage_ns[s.index()] > self.stage_ns[best.index()] {
                best = s;
            }
        }
        best
    }

    fn to_words(self) -> [u64; WORDS] {
        let mut w = [0u64; WORDS];
        w[0] = self.trace_id;
        w[1..1 + Stage::COUNT].copy_from_slice(&self.stage_ns);
        w[Stage::COUNT + 1] = self.total_ns;
        w[Stage::COUNT + 2] = self.unix_ms;
        w[Stage::COUNT + 3] =
            ((self.rows as u64) << 32) | ((self.batch as u64) << 16) | self.status as u64;
        w
    }

    fn from_words(w: &[u64; WORDS]) -> SpanRecord {
        let mut stage_ns = [0u64; Stage::COUNT];
        stage_ns.copy_from_slice(&w[1..1 + Stage::COUNT]);
        let packed = w[Stage::COUNT + 3];
        SpanRecord {
            trace_id: w[0],
            stage_ns,
            total_ns: w[Stage::COUNT + 1],
            unix_ms: w[Stage::COUNT + 2],
            status: (packed & 0xffff) as u16,
            rows: (packed >> 32) as u32,
            batch: ((packed >> 16) & 0xffff) as u32,
        }
    }
}

// ---------------------------------------------------------------------------
// Trace IDs
// ---------------------------------------------------------------------------

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);
static TRACE_SEED: OnceLock<u64> = OnceLock::new();

/// SplitMix64 finalizer — full-avalanche mixing of a counter into an ID
/// that doesn't leak request ordering across restarts.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Mint a new nonzero trace ID. Allocation-free after the first call (a
/// process-wide seed is derived once from wall clock + pid), so it is
/// safe on the zero-allocation inference hot path.
pub fn mint_trace_id() -> u64 {
    let seed = *TRACE_SEED.get_or_init(|| {
        let t = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap_or_default();
        (t.as_nanos() as u64) ^ ((std::process::id() as u64) << 32)
    });
    let n = NEXT_TRACE.fetch_add(1, Ordering::Relaxed);
    let id = mix64(n ^ seed);
    if id == 0 {
        1
    } else {
        id
    }
}

/// Current wall clock in Unix milliseconds.
pub fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default()
        .as_millis() as u64
}

// ---------------------------------------------------------------------------
// Slow-request ring
// ---------------------------------------------------------------------------

/// One ring slot: a per-slot seqlock (`seq` odd = write in progress) over
/// the record's packed words. Readers copy the words and re-check `seq`;
/// a concurrent write makes the copy torn, which the re-check detects and
/// the reader skips the slot. Writers never wait: a slot already being
/// written (only possible after the ring index wraps under extreme load)
/// drops the new sample instead of blocking.
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; WORDS],
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            words: [0u64; WORDS].map(AtomicU64::new),
        }
    }
}

/// Bounded lock-free ring of the most recent slow requests.
///
/// `record` is wait-free for the common case (claim an index with one
/// `fetch_add`, write the words, bump the seqlock) and performs no heap
/// allocation, so publishing a slow request does not break the
/// zero-allocation steady state. `snapshot` (the `/v1/debug/slow`
/// handler) allocates freely — it is a debug surface, not a hot path.
pub struct SlowRing {
    slots: Box<[Slot]>,
    head: AtomicU64,
    threshold_ns: u64,
}

impl SlowRing {
    /// Ring with `capacity` slots capturing requests slower than
    /// `threshold` end-to-end. Capacity is clamped to at least 1.
    pub fn new(capacity: usize, threshold: Duration) -> SlowRing {
        let cap = capacity.max(1);
        SlowRing {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
            threshold_ns: threshold.as_nanos() as u64,
        }
    }

    /// Capture threshold in nanoseconds.
    pub fn threshold_ns(&self) -> u64 {
        self.threshold_ns
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever published (not clamped to capacity).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Publish one record. Lock-free and allocation-free; drops the
    /// sample if the claimed slot is mid-write by a lapped writer.
    pub fn record(&self, rec: &SpanRecord) {
        let i = (self.head.fetch_add(1, Ordering::Relaxed) % self.slots.len() as u64) as usize;
        let slot = &self.slots[i];
        let seq = slot.seq.load(Ordering::Relaxed);
        if seq & 1 == 1 {
            return; // lapped writer still in the slot: drop this sample
        }
        if slot
            .seq
            .compare_exchange(seq, seq + 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return;
        }
        let words = rec.to_words();
        for (w, v) in slot.words.iter().zip(words) {
            w.store(v, Ordering::Relaxed);
        }
        slot.seq.store(seq + 2, Ordering::Release);
    }

    /// Consistent copies of the captured records, newest first. Slots
    /// that are empty or mid-write are skipped.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let live = head.min(cap);
        let mut out = Vec::with_capacity(live as usize);
        for back in 1..=live {
            let i = ((head - back) % cap) as usize;
            let slot = &self.slots[i];
            // Two read attempts: a slot under sustained rewrite is
            // skipped rather than spun on.
            for _ in 0..2 {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 & 1 == 1 {
                    continue;
                }
                let mut words = [0u64; WORDS];
                for (dst, src) in words.iter_mut().zip(slot.words.iter()) {
                    *dst = src.load(Ordering::Relaxed);
                }
                if slot.seq.load(Ordering::Acquire) == s1 {
                    let rec = SpanRecord::from_words(&words);
                    if rec.trace_id != 0 {
                        out.push(rec);
                    }
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn stage_names_are_unique_and_ordered() {
        let names: Vec<_> = Stage::ALL.iter().map(|s| s.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), Stage::COUNT);
        assert_eq!(Stage::ALL[0].index(), 0);
        assert_eq!(Stage::ALL[Stage::COUNT - 1].index(), Stage::COUNT - 1);
    }

    #[test]
    fn span_record_pack_roundtrip() {
        let mut rec = SpanRecord {
            trace_id: 0xdead_beef_1234_5678,
            total_ns: 7_000_001,
            unix_ms: 1_700_000_000_123,
            status: 504,
            rows: 9,
            batch: 128,
            ..Default::default()
        };
        for (i, s) in Stage::ALL.iter().enumerate() {
            rec.set(*s, Duration::from_nanos(1_000 * (i as u64 + 1)));
        }
        let back = SpanRecord::from_words(&rec.to_words());
        assert_eq!(back, rec);
        assert_eq!(back.get(Stage::Write), 7_000);
    }

    #[test]
    fn slowest_stage_picks_max() {
        let mut rec = SpanRecord::default();
        rec.set(Stage::QueueWait, Duration::from_micros(10));
        rec.set(Stage::Execute, Duration::from_micros(900));
        rec.set(Stage::Serialize, Duration::from_micros(20));
        assert_eq!(rec.slowest(), Stage::Execute);
    }

    #[test]
    fn trace_ids_are_nonzero_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            let id = mint_trace_id();
            assert_ne!(id, 0);
            assert!(seen.insert(id), "duplicate trace id {id:#x}");
        }
    }

    #[test]
    fn ring_keeps_newest_and_wraps() {
        let ring = SlowRing::new(4, Duration::from_millis(1));
        for i in 1..=10u64 {
            let rec = SpanRecord {
                trace_id: i,
                total_ns: i * 1_000,
                ..Default::default()
            };
            ring.record(&rec);
        }
        let snap = ring.snapshot();
        let ids: Vec<u64> = snap.iter().map(|r| r.trace_id).collect();
        assert_eq!(ids, vec![10, 9, 8, 7]);
        assert_eq!(ring.recorded(), 10);
        assert_eq!(ring.capacity(), 4);
    }

    #[test]
    fn empty_ring_snapshot_is_empty() {
        let ring = SlowRing::new(8, Duration::from_millis(1));
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn concurrent_writers_and_readers_never_see_torn_records() {
        // Writers publish records whose words are all equal to the trace
        // ID; a torn read would surface as a mismatched word.
        let ring = Arc::new(SlowRing::new(8, Duration::from_millis(1)));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let r = Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                for i in 0..2_000u64 {
                    let v = t * 1_000_000 + i + 1;
                    let rec = SpanRecord {
                        trace_id: v,
                        stage_ns: [v; Stage::COUNT],
                        total_ns: v,
                        unix_ms: v,
                        ..Default::default()
                    };
                    r.record(&rec);
                }
            }));
        }
        let reader = {
            let r = Arc::clone(&ring);
            std::thread::spawn(move || {
                for _ in 0..500 {
                    for rec in r.snapshot() {
                        assert_eq!(rec.stage_ns, [rec.trace_id; Stage::COUNT]);
                        assert_eq!(rec.total_ns, rec.trace_id);
                    }
                }
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        reader.join().unwrap();
    }
}
