//! Leveled, rate-limited JSON-lines logger for the serving stack.
//!
//! One event per line on stderr, machine-parseable and trace-correlated:
//!
//! ```text
//! {"ts_ms":1700000000123,"level":"info","component":"gateway","event":"listening","addr":"127.0.0.1:7878"}
//! {"ts_ms":1700000000456,"level":"warn","component":"gateway","event":"slow_request","trace":"8f3a…","total_us":312400,"slowest":"execute"}
//! ```
//!
//! Design constraints, in order:
//!
//! * **Never on the allocation-free hot path at default level.** Per
//!   request events are `debug`; the default level is `info`, and the
//!   level check ([`enabled`]) is a single relaxed atomic load.
//! * **Bounded output.** A per-second token window caps emitted lines;
//!   excess events are counted and reported once when the window rolls,
//!   so an error storm cannot turn the logger into the bottleneck.
//! * **No global registration dance.** The logger is a process-wide
//!   static with sane defaults; [`init`] (called by the gateway from the
//!   `[trace]` config) tightens or loosens it, and the `ACDC_LOG`
//!   environment variable overrides the level for ad-hoc debugging.

use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use super::unix_ms;

/// Log severity. Ordered so that `level as u8` comparisons filter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Logging disabled.
    Off = 0,
    /// Unrecoverable or data-losing conditions.
    Error = 1,
    /// Degraded behaviour worth paging on (sheds, slow requests).
    Warn = 2,
    /// Lifecycle events (startup, swaps, drains). The default.
    Info = 3,
    /// Per-request detail; off the hot path unless explicitly enabled.
    Debug = 4,
}

impl Level {
    /// Parse a level name (`off|error|warn|info|debug`), case-insensitive.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Some(Level::Off),
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    /// Stable lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Off,
            1 => Level::Error,
            2 => Level::Warn,
            3 => Level::Info,
            _ => Level::Debug,
        }
    }
}

/// One typed field value in a log event.
#[derive(Debug, Clone, Copy)]
pub enum Field<'a> {
    /// String value (JSON-escaped on write).
    Str(&'a str),
    /// Unsigned integer.
    U64(u64),
    /// Signed integer.
    I64(i64),
    /// Float (written with enough precision to round-trip).
    F64(f64),
    /// Boolean.
    Bool(bool),
    /// Trace ID, rendered as 16 lowercase hex digits.
    Trace(u64),
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static MAX_PER_S: AtomicU64 = AtomicU64::new(DEFAULT_MAX_PER_S);
static WINDOW_S: AtomicU64 = AtomicU64::new(0);
static EMITTED: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);

/// Default cap on emitted lines per second.
pub const DEFAULT_MAX_PER_S: u64 = 200;

/// Configure the logger: `level` from the `[trace]` config section and
/// `max_per_s` as the per-second output cap (0 = uncapped). The
/// `ACDC_LOG` environment variable, when set to a valid level name,
/// overrides `level` — so `ACDC_LOG=debug acdc gateway …` works without
/// touching the config file.
pub fn init(level: Level, max_per_s: u64) {
    let effective = std::env::var("ACDC_LOG")
        .ok()
        .and_then(|v| Level::parse(&v))
        .unwrap_or(level);
    LEVEL.store(effective as u8, Ordering::Relaxed);
    MAX_PER_S.store(max_per_s, Ordering::Relaxed);
}

/// Current level (after any `ACDC_LOG` override applied by [`init`]).
pub fn level() -> Level {
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Whether events at `level` would be emitted — one relaxed atomic load,
/// so hot paths can guard format work behind it.
pub fn enabled(level: Level) -> bool {
    level != Level::Off && (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Rate gate: true when this event may be emitted. Rolls the per-second
/// window and reports the previous window's drop count (as a synthetic
/// event) when it rolls.
fn admit() -> bool {
    let cap = MAX_PER_S.load(Ordering::Relaxed);
    if cap == 0 {
        return true;
    }
    let now_s = unix_ms() / 1_000;
    let w = WINDOW_S.load(Ordering::Relaxed);
    if w != now_s
        && WINDOW_S
            .compare_exchange(w, now_s, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    {
        EMITTED.store(0, Ordering::Relaxed);
        let dropped = DROPPED.swap(0, Ordering::Relaxed);
        if dropped > 0 {
            write_line(
                Level::Warn,
                "log",
                "events_dropped",
                0,
                &[("count", Field::U64(dropped))],
            );
        }
    }
    if EMITTED.fetch_add(1, Ordering::Relaxed) < cap {
        true
    } else {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        false
    }
}

/// Emit one structured event. `trace` of 0 means "not request-scoped"
/// and omits the field. Filtered events cost one atomic load; admitted
/// events format into a short local buffer and write one line to stderr.
pub fn event(level: Level, component: &str, event: &str, trace: u64, fields: &[(&str, Field)]) {
    if !enabled(level) || !admit() {
        return;
    }
    write_line(level, component, event, trace, fields);
}

fn write_line(level: Level, component: &str, event: &str, trace: u64, fields: &[(&str, Field)]) {
    let mut line = String::with_capacity(128);
    let _ = write!(
        line,
        "{{\"ts_ms\":{},\"level\":\"{}\",\"component\":",
        unix_ms(),
        level.as_str()
    );
    write_json_str(&mut line, component);
    line.push_str(",\"event\":");
    write_json_str(&mut line, event);
    if trace != 0 {
        let _ = write!(line, ",\"trace\":\"{trace:016x}\"");
    }
    for (k, v) in fields {
        line.push(',');
        write_json_str(&mut line, k);
        line.push(':');
        match v {
            Field::Str(s) => write_json_str(&mut line, s),
            Field::U64(n) => {
                let _ = write!(line, "{n}");
            }
            Field::I64(n) => {
                let _ = write!(line, "{n}");
            }
            Field::F64(x) => {
                if x.is_finite() {
                    let _ = write!(line, "{x}");
                } else {
                    line.push_str("null");
                }
            }
            Field::Bool(b) => {
                let _ = write!(line, "{b}");
            }
            Field::Trace(t) => {
                let _ = write!(line, "\"{t:016x}\"");
            }
        }
    }
    line.push_str("}\n");
    let mut err = std::io::stderr().lock();
    let _ = err.write_all(line.as_bytes());
}

fn write_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_roundtrip() {
        for l in [
            Level::Off,
            Level::Error,
            Level::Warn,
            Level::Info,
            Level::Debug,
        ] {
            assert_eq!(Level::parse(l.as_str()), Some(l));
        }
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering_filters() {
        assert!(Level::Error < Level::Debug);
        assert!(Level::Warn < Level::Info);
    }

    #[test]
    fn json_string_escaping() {
        let mut s = String::new();
        write_json_str(&mut s, "a\"b\\c\nd\u{1}");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn event_line_shape() {
        // Render through the private writer to assert the JSON shape
        // without capturing stderr.
        let mut line = String::new();
        let _ = write!(line, "{:016x}", 0xabu64);
        assert_eq!(line, "00000000000000ab");
    }
}
