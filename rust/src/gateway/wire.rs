//! Length-prefixed binary wire frames for `/v1/infer`.
//!
//! The JSON wire format spends the inference hot path formatting and
//! re-parsing decimal floats — at small model widths that costs more than
//! the transform itself. This module defines a raw little-endian f32
//! frame, negotiated per request via `Content-Type:
//! application/x-acdc-f32`, that skips float text entirely while keeping
//! the JSON path as the compatibility fallback:
//!
//! ```text
//!   request  = "ACF1" ‖ rows:u32le ‖ width:u32le ‖ rows×width f32le
//!   response = "ACR1" ‖ rows:u32le ‖ width:u32le ‖ version:u64le
//!              ‖ queue_us:u64le ‖ execute_us:u64le ‖ rows×width f32le
//! ```
//!
//! Both frames travel as ordinary HTTP bodies (`Content-Length`-framed,
//! keep-alive preserved), so admission control, tracing, and every error
//! path stay identical to the JSON route — errors are always answered as
//! JSON with the **same validation wording** the text parser uses.
//!
//! Bit-identity contract: the payload carries the exact f32 bits of the
//! connection arena, and the JSON path renders those same f32s through
//! shortest-roundtrip decimal — so for identical input rows the two wire
//! formats decode to identical output bits (pinned by the
//! `binary_and_json_paths_agree_bitwise` integration test).

/// The negotiated content type for binary inference frames.
pub const CONTENT_TYPE: &str = "application/x-acdc-f32";

/// Request frame magic (`ACdc F32 v1`).
pub const REQ_MAGIC: [u8; 4] = *b"ACF1";

/// Response frame magic.
pub const RESP_MAGIC: [u8; 4] = *b"ACR1";

/// Request frame header length: magic + rows + width.
pub const REQ_HEADER_BYTES: usize = 12;

/// Response frame header length: magic + rows + width + version +
/// queue_us + execute_us.
pub const RESP_HEADER_BYTES: usize = 36;

/// Whether a request's `Content-Type` selects the binary frame.
pub fn is_binary_content_type(value: &str) -> bool {
    value.trim().eq_ignore_ascii_case(CONTENT_TYPE)
}

#[inline]
fn read_u32le(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

#[inline]
fn read_u64le(b: &[u8], at: usize) -> u64 {
    let mut buf = [0u8; 8];
    buf.copy_from_slice(&b[at..at + 8]);
    u64::from_le_bytes(buf)
}

/// Parse one binary request frame into the connection arena, appending
/// `rows × width` f32s to `out` (cleared first) and returning the row
/// count. Validation semantics — and error wording — match the JSON
/// parsers exactly: empty batches, over-cap batches, width mismatches and
/// non-finite features are rejected with the same messages, so a client
/// switching wire formats sees identical 400s. Zero-allocation once `out`
/// has grown to the request shape.
pub fn parse_binary_request(
    body: &[u8],
    width: usize,
    max_rows: usize,
    out: &mut Vec<f32>,
) -> Result<usize, String> {
    out.clear();
    if body.len() < REQ_HEADER_BYTES {
        return Err(format!(
            "bad binary frame: {} bytes is shorter than the {REQ_HEADER_BYTES}-byte header",
            body.len()
        ));
    }
    if body[..4] != REQ_MAGIC {
        return Err("bad binary frame: wrong magic (expected ACF1)".into());
    }
    let rows = read_u32le(body, 4) as usize;
    let frame_width = read_u32le(body, 8) as usize;
    if rows == 0 {
        return Err("'rows' must not be empty".into());
    }
    if rows > max_rows {
        return Err(format!("too many rows ({rows} > {max_rows})"));
    }
    if frame_width != width {
        return Err(format!(
            "row has {frame_width} features, model width is {width}"
        ));
    }
    // rows ≤ max_rows and width was validated against the model, so this
    // product cannot overflow in practice; checked anyway to keep the
    // frame parser total.
    let payload = rows
        .checked_mul(width)
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| "bad binary frame: payload size overflow".to_string())?;
    if body.len() != REQ_HEADER_BYTES + payload {
        return Err(format!(
            "bad binary frame: {} payload bytes, header declares {payload}",
            body.len() - REQ_HEADER_BYTES
        ));
    }
    out.reserve(rows * width);
    for chunk in body[REQ_HEADER_BYTES..].chunks_exact(4) {
        let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        if !v.is_finite() {
            out.clear();
            return Err("features must be finite numbers".into());
        }
        out.push(v);
    }
    Ok(rows)
}

/// Render one binary request frame into a reused buffer: `vals` holds
/// `rows × width` row-major features. The load generator's `--binary`
/// mode and the wire tests share this writer.
pub fn write_binary_request(buf: &mut Vec<u8>, width: usize, vals: &[f32]) {
    debug_assert!(width > 0 && vals.len() % width == 0);
    let rows = vals.len() / width;
    buf.clear();
    buf.extend_from_slice(&REQ_MAGIC);
    buf.extend_from_slice(&(rows as u32).to_le_bytes());
    buf.extend_from_slice(&(width as u32).to_le_bytes());
    for v in vals {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Serialize a success response frame straight into the connection's
/// reusable write buffer — the binary counterpart of the JSON body
/// writer. `outs` is the arena's row-major `[rows, stride]` output
/// buffer; each row carries `out_lens[r]` valid floats (uniform across
/// rows — one model, one output width).
#[allow(clippy::too_many_arguments)]
pub fn write_binary_response(
    buf: &mut Vec<u8>,
    rows: usize,
    stride: usize,
    version: u64,
    queue_us: u64,
    execute_us: u64,
    outs: &[f32],
    out_lens: &[usize],
) {
    let out_width = out_lens.first().copied().unwrap_or(0);
    debug_assert!(out_lens[..rows].iter().all(|&l| l == out_width));
    buf.clear();
    buf.extend_from_slice(&RESP_MAGIC);
    buf.extend_from_slice(&(rows as u32).to_le_bytes());
    buf.extend_from_slice(&(out_width as u32).to_le_bytes());
    buf.extend_from_slice(&version.to_le_bytes());
    buf.extend_from_slice(&queue_us.to_le_bytes());
    buf.extend_from_slice(&execute_us.to_le_bytes());
    for r in 0..rows {
        let start = r * stride;
        for v in &outs[start..start + out_lens[r]] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Decoded response frame header (client side: loadgen, tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinaryResponseHead {
    /// Output row count.
    pub rows: usize,
    /// Floats per output row.
    pub width: usize,
    /// Serving model version.
    pub version: u64,
    /// Worst per-row coordinator queue wait, microseconds.
    pub queue_us: u64,
    /// Worst per-row executor time, microseconds.
    pub execute_us: u64,
}

/// Parse one response frame, appending the payload floats to `out`
/// (cleared first). Exact bits are preserved — this is the comparison
/// side of the binary/JSON bit-identity contract.
pub fn parse_binary_response(
    body: &[u8],
    out: &mut Vec<f32>,
) -> Result<BinaryResponseHead, String> {
    out.clear();
    if body.len() < RESP_HEADER_BYTES {
        return Err(format!(
            "bad binary frame: {} bytes is shorter than the {RESP_HEADER_BYTES}-byte header",
            body.len()
        ));
    }
    if body[..4] != RESP_MAGIC {
        return Err("bad binary frame: wrong magic (expected ACR1)".into());
    }
    let head = BinaryResponseHead {
        rows: read_u32le(body, 4) as usize,
        width: read_u32le(body, 8) as usize,
        version: read_u64le(body, 12),
        queue_us: read_u64le(body, 20),
        execute_us: read_u64le(body, 28),
    };
    let payload = head
        .rows
        .checked_mul(head.width)
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| "bad binary frame: payload size overflow".to_string())?;
    if body.len() != RESP_HEADER_BYTES + payload {
        return Err(format!(
            "bad binary frame: {} payload bytes, header declares {payload}",
            body.len() - RESP_HEADER_BYTES
        ));
    }
    out.reserve(head.rows * head.width);
    for chunk in body[RESP_HEADER_BYTES..].chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(head)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_frame_roundtrips_bit_exact() {
        let vals: Vec<f32> = vec![1.0, -2.5, 3.0e-8, f32::MIN_POSITIVE, 0.0, -0.0];
        let mut buf = Vec::new();
        write_binary_request(&mut buf, 3, &vals);
        assert_eq!(buf.len(), REQ_HEADER_BYTES + vals.len() * 4);
        let mut out = Vec::new();
        let rows = parse_binary_request(&buf, 3, 8, &mut out).unwrap();
        assert_eq!(rows, 2);
        for (a, b) in vals.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits(), "payload bits must survive");
        }
    }

    #[test]
    fn request_validation_matches_json_wording() {
        let mut out = Vec::new();
        let mut buf = Vec::new();
        // Width mismatch: the frame says 3, the model says 4.
        write_binary_request(&mut buf, 3, &[0.0; 3]);
        let err = parse_binary_request(&buf, 4, 8, &mut out).unwrap_err();
        assert_eq!(err, "row has 3 features, model width is 4");
        // Empty batch.
        let mut empty = Vec::new();
        empty.extend_from_slice(&REQ_MAGIC);
        empty.extend_from_slice(&0u32.to_le_bytes());
        empty.extend_from_slice(&3u32.to_le_bytes());
        let err = parse_binary_request(&empty, 3, 8, &mut out).unwrap_err();
        assert_eq!(err, "'rows' must not be empty");
        // Over-cap batch.
        write_binary_request(&mut buf, 2, &[0.0; 6]);
        let err = parse_binary_request(&buf, 2, 2, &mut out).unwrap_err();
        assert_eq!(err, "too many rows (3 > 2)");
        // Non-finite features carry the JSON wording too.
        write_binary_request(&mut buf, 2, &[1.0, f32::NAN]);
        let err = parse_binary_request(&buf, 2, 8, &mut out).unwrap_err();
        assert_eq!(err, "features must be finite numbers");
        assert!(out.is_empty(), "rejected frames must not leak rows");
    }

    #[test]
    fn request_frame_anomalies_are_rejected() {
        let mut out = Vec::new();
        assert!(parse_binary_request(b"ACF1", 2, 8, &mut out)
            .unwrap_err()
            .contains("shorter than"));
        let mut bad_magic = Vec::new();
        write_binary_request(&mut bad_magic, 2, &[0.0; 2]);
        bad_magic[0] = b'X';
        assert!(parse_binary_request(&bad_magic, 2, 8, &mut out)
            .unwrap_err()
            .contains("magic"));
        // Truncated / padded payloads never parse.
        let mut frame = Vec::new();
        write_binary_request(&mut frame, 2, &[0.5; 2]);
        assert!(parse_binary_request(&frame[..frame.len() - 1], 2, 8, &mut out).is_err());
        frame.push(0);
        assert!(parse_binary_request(&frame, 2, 8, &mut out).is_err());
    }

    #[test]
    fn response_frame_roundtrips_header_and_bits() {
        // Arena layout: stride 4, two rows of 3 valid floats each.
        let outs = [1.0f32, 2.0, 3.0, 99.0, -1.0, -2.0, -3.0, 99.0];
        let out_lens = [3usize, 3];
        let mut buf = Vec::new();
        write_binary_response(&mut buf, 2, 4, 7, 17, 42, &outs, &out_lens);
        assert_eq!(buf.len(), RESP_HEADER_BYTES + 2 * 3 * 4);
        let mut payload = Vec::new();
        let head = parse_binary_response(&buf, &mut payload).unwrap();
        assert_eq!(
            head,
            BinaryResponseHead {
                rows: 2,
                width: 3,
                version: 7,
                queue_us: 17,
                execute_us: 42,
            }
        );
        let want = [1.0f32, 2.0, 3.0, -1.0, -2.0, -3.0];
        assert_eq!(payload.len(), want.len());
        for (a, b) in want.iter().zip(&payload) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn content_type_negotiation() {
        assert!(is_binary_content_type("application/x-acdc-f32"));
        assert!(is_binary_content_type(" Application/X-ACDC-F32 "));
        assert!(!is_binary_content_type("application/json"));
        assert!(!is_binary_content_type(""));
    }
}
