//! Brownout degradation: a gateway under sustained pressure walks a
//! ladder of progressively cheaper service levels instead of falling
//! over, and walks back down when the pressure clears.
//!
//! The controller thread samples two pressure signals every
//! `brownout.tick_ms`: the admission in-flight gauge against its cap
//! (`hot_inflight_pct`) and the coordinator queue depth
//! (`hot_queue_depth`, 0 = disabled). `up_after` consecutive hot ticks
//! raise the level by one; `down_after` consecutive cool ticks lower it
//! by one — hysteresis in both directions, so a flapping signal cannot
//! oscillate the service level per tick. The levels:
//!
//! | level | degradation                                             |
//! |-------|---------------------------------------------------------|
//! | 0     | normal service                                          |
//! | 1     | cluster hedging disabled (no duplicate upstream work)   |
//! | 2     | trace sampling coarsened by `sample_coarsen`            |
//! | 3     | multi-row (batch) inference requests shed with 503      |
//! | 4     | everything but `/healthz` and `/metrics` shed with 503  |
//!
//! Each level includes the ones below it. The current level is exported
//! as the `brownout.level` gauge (`acdc_brownout_level` on
//! `GET /metrics`), sheds are counted in `gateway.brownout_shed`, and
//! every transition emits a structured `brownout_level` log event.
//!
//! The ladder itself ([`Ladder`]) is a pure state machine over "was this
//! tick hot" booleans, so the hysteresis is unit-testable without
//! threads or clocks.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::admission::Admission;
use crate::cluster::RouterCore;
use crate::config::BrownoutConfig;
use crate::metrics::{Counter, Gauge, Registry};
use crate::trace::log::{self, Field, Level};

/// Level at which cluster hedging is disabled.
pub const LEVEL_NO_HEDGE: u64 = 1;
/// Level at which trace sampling is coarsened.
pub const LEVEL_COARSE_TRACE: u64 = 2;
/// Level at which multi-row requests are shed.
pub const LEVEL_SHED_BATCH: u64 = 3;
/// Level at which all non-health traffic is shed.
pub const LEVEL_SHED_ALL: u64 = 4;
/// The ladder's top rung.
pub const MAX_LEVEL: u64 = 4;

/// Shared brownout state read on the request path: the current level,
/// the effective trace sampling stride, and the shed counter. All reads
/// are single atomics — level 0 costs one load per request.
pub struct Brownout {
    level: AtomicU64,
    /// Effective `trace.sample_every` (base value, or base × coarsen at
    /// [`LEVEL_COARSE_TRACE`] and above).
    sample_every: AtomicU64,
    base_sample_every: u64,
    coarsen: u64,
    shed: Arc<Counter>,
    gauge: Arc<Gauge>,
}

impl Brownout {
    /// Fresh state at level 0. `base_sample_every` is the configured
    /// `trace.sample_every` (already floored at 1 by the caller).
    pub fn new(base_sample_every: u64, coarsen: u64, metrics: &Registry) -> Brownout {
        Brownout {
            level: AtomicU64::new(0),
            sample_every: AtomicU64::new(base_sample_every),
            base_sample_every,
            coarsen: coarsen.max(1),
            shed: metrics.counter("gateway.brownout_shed"),
            gauge: metrics.gauge("brownout.level"),
        }
    }

    /// Current degradation level (0 = normal service).
    pub fn level(&self) -> u64 {
        self.level.load(Ordering::Acquire)
    }

    /// The trace sampling stride the gateway should use right now.
    pub fn effective_sample_every(&self) -> u64 {
        self.sample_every.load(Ordering::Relaxed).max(1)
    }

    /// Count one request shed by a brownout level.
    pub fn note_shed(&self) {
        self.shed.inc();
    }

    /// Apply `level`: store it, mirror the gauge, and recompute the
    /// effective sampling stride. Called by the controller on ladder
    /// transitions (and by tests directly).
    pub fn apply(&self, level: u64) {
        let level = level.min(MAX_LEVEL);
        self.level.store(level, Ordering::Release);
        self.gauge.set(level);
        let stride = if level >= LEVEL_COARSE_TRACE {
            self.base_sample_every.saturating_mul(self.coarsen)
        } else {
            self.base_sample_every
        };
        self.sample_every.store(stride.max(1), Ordering::Relaxed);
    }
}

/// The pure hysteresis ladder: consecutive hot ticks climb, consecutive
/// cool ticks descend, and any flip of the signal resets the opposing
/// streak.
pub struct Ladder {
    level: u64,
    hot_streak: u64,
    cool_streak: u64,
    up_after: u64,
    down_after: u64,
}

impl Ladder {
    /// Ladder at level 0 with the given hysteresis thresholds (both
    /// floored at 1).
    pub fn new(up_after: u64, down_after: u64) -> Ladder {
        Ladder {
            level: 0,
            hot_streak: 0,
            cool_streak: 0,
            up_after: up_after.max(1),
            down_after: down_after.max(1),
        }
    }

    /// Current level.
    pub fn level(&self) -> u64 {
        self.level
    }

    /// Feed one tick's pressure verdict; returns `Some(new_level)` when
    /// the level changed. A climb or descent consumes the streak that
    /// triggered it, so moving two rungs takes two full streaks.
    pub fn tick(&mut self, hot: bool) -> Option<u64> {
        if hot {
            self.cool_streak = 0;
            self.hot_streak += 1;
            if self.hot_streak >= self.up_after && self.level < MAX_LEVEL {
                self.hot_streak = 0;
                self.level += 1;
                return Some(self.level);
            }
        } else {
            self.hot_streak = 0;
            self.cool_streak += 1;
            if self.cool_streak >= self.down_after && self.level > 0 {
                self.cool_streak = 0;
                self.level -= 1;
                return Some(self.level);
            }
        }
        None
    }
}

/// Whether a tick is "hot" given the two pressure readings and their
/// thresholds. `max_inflight == 0` or `hot_queue_depth == 0` disables
/// the respective signal.
pub fn is_hot(
    inflight: u64,
    max_inflight: u64,
    queue_depth: u64,
    hot_inflight_pct: f64,
    hot_queue_depth: u64,
) -> bool {
    let inflight_hot =
        max_inflight > 0 && inflight as f64 >= hot_inflight_pct * max_inflight as f64;
    let queue_hot = hot_queue_depth > 0 && queue_depth >= hot_queue_depth;
    inflight_hot || queue_hot
}

/// The background controller: owns the sampling thread driving a
/// [`Ladder`] against live gauges and applying transitions to the shared
/// [`Brownout`] state (and the router's hedging switch).
pub struct Controller {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Controller {
    /// Spawn the controller thread. `depth` is the coordinator
    /// queue-depth gauge (stays 0 on the router role, where the
    /// in-flight signal carries the pressure).
    pub fn start(
        cfg: BrownoutConfig,
        state: Arc<Brownout>,
        admission: Arc<Admission>,
        depth: Arc<Gauge>,
        router: Option<Arc<RouterCore>>,
    ) -> Result<Controller, String> {
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("acdc-gw-brownout".into())
            .spawn(move || {
                let tick = Duration::from_millis(cfg.tick_ms.max(1));
                let mut ladder = Ladder::new(cfg.up_after, cfg.down_after);
                while !thread_stop.load(Ordering::Acquire) {
                    std::thread::sleep(tick);
                    let inflight = admission.inflight();
                    let queue_depth = depth.get();
                    let hot = is_hot(
                        inflight,
                        admission.max_inflight(),
                        queue_depth,
                        cfg.hot_inflight_pct,
                        cfg.hot_queue_depth,
                    );
                    if let Some(level) = ladder.tick(hot) {
                        state.apply(level);
                        if let Some(router) = &router {
                            router.set_hedging(level < LEVEL_NO_HEDGE);
                        }
                        log::event(
                            Level::Warn,
                            "gateway",
                            "brownout_level",
                            0,
                            &[
                                ("level", Field::U64(level)),
                                ("inflight", Field::U64(inflight)),
                                ("queue_depth", Field::U64(queue_depth)),
                                (
                                    "sample_every",
                                    Field::U64(state.effective_sample_every()),
                                ),
                            ],
                        );
                    }
                }
                // Leave the gateway at full service on shutdown so a
                // restart-free controller swap never strands a level.
                state.apply(0);
                if let Some(router) = &router {
                    router.set_hedging(true);
                }
            })
            .map_err(|e| format!("spawn brownout controller: {e}"))?;
        Ok(Controller {
            stop,
            handle: Some(handle),
        })
    }

    /// Stop and join the controller thread (idempotent).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Controller {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_climbs_after_up_after_hot_ticks_only() {
        let mut l = Ladder::new(3, 2);
        assert_eq!(l.tick(true), None);
        assert_eq!(l.tick(true), None);
        assert_eq!(l.tick(true), Some(1), "third consecutive hot tick climbs");
        // The streak was consumed: the next rung takes three more.
        assert_eq!(l.tick(true), None);
        assert_eq!(l.tick(true), None);
        assert_eq!(l.tick(true), Some(2));
    }

    #[test]
    fn ladder_cool_tick_resets_hot_streak() {
        let mut l = Ladder::new(2, 5);
        assert_eq!(l.tick(true), None);
        assert_eq!(l.tick(false), None, "cool tick resets the hot streak");
        assert_eq!(l.tick(true), None);
        assert_eq!(l.tick(true), Some(1));
    }

    #[test]
    fn ladder_descends_with_its_own_hysteresis_and_floors_at_zero() {
        let mut l = Ladder::new(1, 2);
        assert_eq!(l.tick(true), Some(1));
        assert_eq!(l.tick(true), Some(2));
        assert_eq!(l.tick(false), None);
        assert_eq!(l.tick(false), Some(1), "two cool ticks descend one rung");
        assert_eq!(l.tick(false), None);
        assert_eq!(l.tick(false), Some(0));
        assert_eq!(l.tick(false), None, "level saturates at 0");
        assert_eq!(l.level(), 0);
    }

    #[test]
    fn ladder_caps_at_max_level() {
        let mut l = Ladder::new(1, 1);
        for want in 1..=MAX_LEVEL {
            assert_eq!(l.tick(true), Some(want));
        }
        assert_eq!(l.tick(true), None, "level saturates at MAX_LEVEL");
        assert_eq!(l.level(), MAX_LEVEL);
    }

    #[test]
    fn hot_predicate_combines_inflight_and_queue_signals() {
        // 80% of 10 = 8.
        assert!(is_hot(8, 10, 0, 0.8, 0));
        assert!(!is_hot(7, 10, 0, 0.8, 0));
        // Queue signal disabled at 0, active otherwise.
        assert!(!is_hot(0, 10, 100, 0.8, 0));
        assert!(is_hot(0, 10, 100, 0.8, 50));
        assert!(!is_hot(0, 10, 49, 0.8, 50));
        // max_inflight = 0 disables the in-flight signal.
        assert!(!is_hot(5, 0, 0, 0.8, 0));
    }

    #[test]
    fn brownout_state_applies_levels_and_sampling_stride() {
        let metrics = Registry::new();
        let b = Brownout::new(2, 8, &metrics);
        assert_eq!(b.level(), 0);
        assert_eq!(b.effective_sample_every(), 2);
        b.apply(LEVEL_NO_HEDGE);
        assert_eq!(b.effective_sample_every(), 2, "level 1 keeps sampling");
        b.apply(LEVEL_COARSE_TRACE);
        assert_eq!(b.effective_sample_every(), 16, "level 2 coarsens ×8");
        assert_eq!(metrics.gauge("brownout.level").get(), 2);
        b.apply(0);
        assert_eq!(b.effective_sample_every(), 2);
        b.apply(99);
        assert_eq!(b.level(), MAX_LEVEL, "apply clamps to the top rung");
        b.note_shed();
        assert_eq!(metrics.counter("gateway.brownout_shed").get(), 1);
    }
}
