//! Dependency-free epoll reactor: the default gateway I/O architecture.
//!
//! One acceptor thread feeds accepted sockets to N event-loop shards.
//! Each shard owns an epoll instance and parks its connections there —
//! parked connections cost one fd and one arena, no thread, which is
//! what lets an integration test hold 10k+ idle keep-alive connections.
//! A shard accumulates inbound bytes per connection and asks
//! [`http::scan_request_frame`] whether a parse attempt can terminate;
//! only then does it hand the connection (a `Box` moved by pointer, no
//! copy) to the bounded dispatch pool, which runs the same
//! `serve_request` pipeline as the threaded fallback — parse → admission
//! → infer → serialize → write — and then re-registers the connection
//! with its shard for the next request.
//!
//! Per-connection state machine:
//!
//! ```text
//!   accept ─▶ [shard: read header ─▶ read body] ─▶ dispatch pool
//!                 ▲      (epoll-driven, non-blocking)     │
//!                 │                                       ▼
//!                 └──────── re-register ◀─── serve_request + write
//! ```
//!
//! Everything here is `libc`-level via four `extern "C"` declarations
//! (`epoll_create1`/`epoll_ctl`/`epoll_wait`, `eventfd`, `poll`) — no
//! new crates. Responses are written by the dispatch worker through a
//! poll-bounded non-blocking writer: a peer that stops reading trips the
//! `gateway.write_stall_ms` deadline and is evicted instead of wedging a
//! worker (the write-stall bug this PR fixes on both paths).
//!
//! Drain protocol (`Gateway::drop` → [`Reactor::shutdown`]): the stop
//! flag is already set; shutdown marks the dispatch queue stopped, wakes
//! every shard's eventfd and the pool condvar, then joins. Shards close
//! parked and mid-frame connections and mark their inboxes closed (a
//! worker returning a connection afterwards drops it instead); workers
//! finish in-flight requests — bounded by the request and write-stall
//! deadlines — writing `connection: close` responses. Every connection's
//! `ConnSlot` releases its `ConnTracker` slot on drop, so the tracker
//! reads zero when shutdown returns and the gateway's `wait_idle`
//! barrier is immediate.
//!
//! The zero-allocation steady state survives the handoffs: connection
//! state is boxed once at accept, the shard map and queues retain their
//! capacity, frame scanning borrows the read buffer, and the dispatch
//! queue is a mutex-guarded `VecDeque` (std's mpsc channel allocates per
//! send; this does not). `tests/zero_alloc.rs` pins this on both wire
//! formats.

use std::collections::{HashMap, VecDeque};
use std::fs::File;
use std::io::{self, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::http::{self, FrameScan, ScratchOutcome};
use super::server::{self, ConnBufs, ConnSlot, Shared};

/// Raw syscall surface. Numeric constants are the x86-64/aarch64 Linux
/// ABI values (uapi `eventpoll.h`, `eventfd.h`, `poll.h`).
mod sys {
    use std::os::raw::{c_int, c_ulong};

    pub const EPOLL_CLOEXEC: c_int = 0x80000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EFD_CLOEXEC: c_int = 0x80000;
    pub const EFD_NONBLOCK: c_int = 0x800;
    pub const POLLOUT: i16 = 0x4;

    /// Mirrors `struct epoll_event`. Packed on x86-64 (the kernel ABI is
    /// 12 bytes there), naturally aligned elsewhere.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    /// Mirrors `struct pollfd`.
    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        pub fn eventfd(initval: u32, flags: c_int) -> c_int;
        pub fn poll(fds: *mut PollFd, nfds: c_ulong, timeout_ms: c_int) -> c_int;
    }
}

/// `epoll_event.data` sentinel for a shard's wake eventfd (fds are
/// non-negative `i32`s, so this can never collide).
const WAKE_TOKEN: u64 = u64::MAX;

/// Shard tick: epoll timeout bounding how fast parked connections notice
/// a drain (mirrors the threaded path's `IDLE_POLL`).
const TICK_MS: i32 = 50;

/// A connection stuck mid-frame longer than this is closed by the stall
/// sweep (mirrors the blocking parser's read-stall deadline).
const STALL_DEADLINE: Duration = Duration::from_secs(10);

/// How often a shard runs its stall sweep.
const SWEEP_EVERY: Duration = Duration::from_secs(1);

/// Read-buffer growth step; bounded by [`frame_cap`].
const READ_CHUNK: usize = 16 * 1024;

/// Epoll events drained per `epoll_wait` call.
const EVENT_BATCH: usize = 256;

/// Upper bound on buffered bytes for one frame: the body cap plus the
/// header-section cap plus request-line slack. At this size the scanner
/// is guaranteed to report `Ready` (complete frame or committed parse
/// error), so `pump_read` dispatching at the cap cannot spin.
fn frame_cap(max_body: usize) -> usize {
    max_body + http::MAX_HEADER_BYTES + 64 * 1024
}

/// One reactor-owned connection: the socket, its accumulated inbound
/// bytes, and the same reusable per-request buffers a threaded
/// connection owns. Boxed once at accept and moved (a pointer) between
/// shard and dispatch pool thereafter.
pub(super) struct Conn {
    stream: TcpStream,
    /// Accumulated inbound bytes not yet consumed by the parser.
    rbuf: Vec<u8>,
    /// Valid prefix of `rbuf`.
    rlen: usize,
    /// Total frame size once the header section is complete
    /// ([`FrameScan::NeedBody`]); 0 = unknown. Saves rescanning the
    /// header while a large body streams in.
    need: usize,
    /// Arrival time of the oldest unconsumed byte (stall-sweep clock).
    partial_since: Option<Instant>,
    /// Parse scratch, inference arena, response write buffers.
    bufs: ConnBufs,
    /// Index of the shard that owns this connection.
    shard: usize,
    /// Releases the `ConnTracker` slot when the connection drops.
    _slot: ConnSlot,
}

/// What a shard should do with a connection after draining its socket.
enum Pump {
    /// Stay parked; wait for more bytes.
    Park,
    /// A parse attempt terminates: hand to the dispatch pool.
    Dispatch,
    /// Peer closed or errored: drop the connection.
    Close,
}

/// Shard state shared between the shard thread, the acceptor and the
/// dispatch workers.
struct Shard {
    /// The shard's epoll instance.
    epfd: OwnedFd,
    /// Eventfd the acceptor/workers write to interrupt `epoll_wait`
    /// (`File` so std's `Read`/`Write` impls cover the fd I/O).
    wake: File,
    /// Connections queued for this shard to adopt (freshly accepted, or
    /// returned by a dispatch worker after a response).
    inbox: Mutex<Inbox>,
}

#[derive(Default)]
struct Inbox {
    queue: VecDeque<Box<Conn>>,
    /// Set under the lock when the shard exits: a connection pushed
    /// afterwards would never be adopted, so the pusher drops it.
    closed: bool,
}

impl Shard {
    fn new() -> io::Result<Shard> {
        let ep = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if ep < 0 {
            return Err(io::Error::last_os_error());
        }
        let epfd = unsafe { OwnedFd::from_raw_fd(ep) };
        let efd = unsafe { sys::eventfd(0, sys::EFD_NONBLOCK | sys::EFD_CLOEXEC) };
        if efd < 0 {
            return Err(io::Error::last_os_error());
        }
        let wake = File::from(unsafe { OwnedFd::from_raw_fd(efd) });
        let mut ev = sys::EpollEvent {
            events: sys::EPOLLIN,
            data: WAKE_TOKEN,
        };
        let rc = unsafe {
            sys::epoll_ctl(
                epfd.as_raw_fd(),
                sys::EPOLL_CTL_ADD,
                wake.as_raw_fd(),
                &mut ev,
            )
        };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Shard {
            epfd,
            wake,
            inbox: Mutex::new(Inbox::default()),
        })
    }

    /// Interrupt this shard's `epoll_wait` (inbox push, drain).
    fn wake(&self) {
        let _ = (&self.wake).write_all(&1u64.to_le_bytes());
    }

    /// Reset the wake eventfd's counter after an interrupt.
    fn drain_wake(&self) {
        let mut buf = [0u8; 8];
        let _ = (&self.wake).read(&mut buf);
    }

    /// Queue a connection for adoption unless the shard already exited;
    /// returns whether it was accepted (a refused conn should be
    /// dropped, releasing its tracker slot).
    fn adopt(&self, conn: Box<Conn>) -> bool {
        {
            let mut inbox = self.inbox.lock().unwrap();
            if inbox.closed {
                return false;
            }
            inbox.queue.push_back(conn);
        }
        self.wake();
        true
    }
}

/// The bounded dispatch pool: workers pull complete-frame connections
/// and run the shared request pipeline. A mutex + condvar around a
/// `VecDeque` (not std mpsc, which allocates per send).
struct DispatchPool {
    q: Mutex<PoolQueue>,
    cv: Condvar,
}

#[derive(Default)]
struct PoolQueue {
    queue: VecDeque<Box<Conn>>,
    stop: bool,
}

impl DispatchPool {
    fn submit(&self, conn: Box<Conn>) {
        {
            let mut q = self.q.lock().unwrap();
            q.queue.push_back(conn);
        }
        self.cv.notify_one();
    }
}

/// Running reactor handle: shard/worker/acceptor threads and their
/// shared queues. Owned by the `Gateway`.
pub(super) struct Reactor {
    shards: Arc<Vec<Shard>>,
    pool: Arc<DispatchPool>,
    accept: JoinHandle<()>,
    shard_threads: Vec<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Reactor {
    /// Spawn the acceptor, `gateway.shards` event loops and
    /// `gateway.dispatch_threads` workers over an already-bound
    /// non-blocking listener.
    pub(super) fn start(shared: Arc<Shared>, listener: TcpListener) -> Result<Reactor, String> {
        let nshards = shared.cfg.shards.max(1);
        let nworkers = shared.cfg.dispatch_threads.max(1);
        let mut shards = Vec::with_capacity(nshards);
        for i in 0..nshards {
            shards.push(Shard::new().map_err(|e| format!("gateway shard {i}: {e}"))?);
        }
        let shards = Arc::new(shards);
        let pool = Arc::new(DispatchPool {
            q: Mutex::new(PoolQueue::default()),
            cv: Condvar::new(),
        });
        let mut shard_threads = Vec::with_capacity(nshards);
        for i in 0..nshards {
            let (sh, sd, pl) = (Arc::clone(&shared), Arc::clone(&shards), Arc::clone(&pool));
            let h = std::thread::Builder::new()
                .name(format!("acdc-gw-shard-{i}"))
                .spawn(move || shard_loop(sh, sd, i, pl))
                .map_err(|e| format!("spawn gateway shard {i}: {e}"))?;
            shard_threads.push(h);
        }
        let mut workers = Vec::with_capacity(nworkers);
        for i in 0..nworkers {
            let (sh, sd, pl) = (Arc::clone(&shared), Arc::clone(&shards), Arc::clone(&pool));
            let h = std::thread::Builder::new()
                .name(format!("acdc-gw-dispatch-{i}"))
                .spawn(move || dispatch_loop(sh, sd, pl))
                .map_err(|e| format!("spawn gateway dispatch {i}: {e}"))?;
            workers.push(h);
        }
        let (sh, sd) = (Arc::clone(&shared), Arc::clone(&shards));
        let accept = std::thread::Builder::new()
            .name("acdc-gw-accept".into())
            .spawn(move || accept_loop(listener, sh, sd))
            .map_err(|e| format!("spawn accept loop: {e}"))?;
        Ok(Reactor {
            shards,
            pool,
            accept,
            shard_threads,
            workers,
        })
    }

    /// Drain and join (see the module docs for the protocol). The
    /// gateway has already set `Shared.stop`; every connection is closed
    /// and every tracker slot released when this returns.
    pub(super) fn shutdown(self) {
        {
            let mut q = self.pool.q.lock().unwrap();
            q.stop = true;
        }
        self.pool.cv.notify_all();
        for s in self.shards.iter() {
            s.wake();
        }
        let _ = self.accept.join();
        for h in self.shard_threads {
            let _ = h.join();
        }
        for h in self.workers {
            let _ = h.join();
        }
        // A connection submitted between a worker's last queue check and
        // its shard closing would sit here unserved; drop any stragglers
        // so their tracker slots release before the drain barrier.
        self.pool.q.lock().unwrap().queue.clear();
    }
}

/// Reactor-mode acceptor: cap-check against the `ConnTracker`, then
/// round-robin the boxed connection to a shard.
fn accept_loop(listener: TcpListener, shared: Arc<Shared>, shards: Arc<Vec<Shard>>) {
    let mut next = 0usize;
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.conns_total.inc();
                if !shared.conns.try_enter(shared.cfg.max_open_conns as u64) {
                    shared.conns_rejected.inc();
                    server::reject_connection(stream, shared.cfg.retry_after_s);
                    continue;
                }
                let slot = ConnSlot(Arc::clone(&shared));
                if stream.set_nonblocking(true).is_err() {
                    continue; // dropping `slot` releases the count
                }
                let _ = stream.set_nodelay(true);
                let idx = next % shards.len();
                next = next.wrapping_add(1);
                let conn = Box::new(Conn {
                    stream,
                    rbuf: Vec::new(),
                    rlen: 0,
                    need: 0,
                    partial_since: None,
                    bufs: ConnBufs::new(),
                    shard: idx,
                    _slot: slot,
                });
                // `adopt` refusing it (shard already exited) drops the
                // conn, releasing its tracker slot.
                shards[idx].adopt(conn);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// One event-loop shard: park connections in epoll, accumulate bytes,
/// dispatch complete frames, sweep stalled peers, close everything on
/// drain.
fn shard_loop(shared: Arc<Shared>, shards: Arc<Vec<Shard>>, idx: usize, pool: Arc<DispatchPool>) {
    let me = &shards[idx];
    let ep = me.epfd.as_raw_fd();
    let max_body = shared.cfg.max_body_bytes;
    let mut conns: HashMap<RawFd, Box<Conn>> = HashMap::new();
    let zero = sys::EpollEvent { events: 0, data: 0 };
    let mut events = vec![zero; EVENT_BATCH];
    let mut sweep: Vec<RawFd> = Vec::new();
    let mut last_sweep = Instant::now();
    loop {
        if shared.stop.load(Ordering::Acquire) || shared.admission.is_draining() {
            break;
        }
        let n = unsafe { sys::epoll_wait(ep, events.as_mut_ptr(), events.len() as i32, TICK_MS) };
        if n < 0 {
            if io::Error::last_os_error().kind() == ErrorKind::Interrupted {
                continue;
            }
            break; // unrecoverable epoll failure; drain cleans up below
        }
        for ev in &events[..n as usize] {
            let data = ev.data;
            if data == WAKE_TOKEN {
                me.drain_wake();
                continue;
            }
            let fd = data as RawFd;
            // Level-triggered: a stale event for an fd the pool now owns
            // cannot arrive — the fd is deleted from epoll before the
            // conn moves.
            let Some(conn) = conns.get_mut(&fd) else {
                continue;
            };
            match pump_read(conn, max_body) {
                Pump::Park => {}
                Pump::Dispatch => {
                    epoll_del(ep, fd);
                    if let Some(conn) = conns.remove(&fd) {
                        pool.submit(conn);
                    }
                }
                Pump::Close => {
                    epoll_del(ep, fd);
                    conns.remove(&fd);
                }
            }
        }
        // Adopt inbox connections (accepted, or returned by a worker). A
        // returned conn can already hold a complete pipelined frame — in
        // that case it goes straight back to the pool.
        loop {
            let conn = { me.inbox.lock().unwrap().queue.pop_front() };
            let Some(mut conn) = conn else { break };
            match http::scan_request_frame(&conn.rbuf[..conn.rlen], max_body) {
                FrameScan::Ready => pool.submit(conn),
                scan => {
                    if let FrameScan::NeedBody(total) = scan {
                        conn.need = total;
                    }
                    register(ep, conn, &mut conns);
                }
            }
        }
        // Stall sweep: a peer stuck mid-frame past the deadline is
        // closed (the non-blocking mirror of the parser's read-stall
        // deadline on the threaded path).
        let now = Instant::now();
        if now.duration_since(last_sweep) >= SWEEP_EVERY {
            last_sweep = now;
            sweep.clear();
            for (fd, conn) in conns.iter() {
                if let Some(t0) = conn.partial_since {
                    if now.duration_since(t0) >= STALL_DEADLINE {
                        sweep.push(*fd);
                    }
                }
            }
            for fd in &sweep {
                epoll_del(ep, *fd);
                conns.remove(fd);
            }
        }
    }
    // Drain: close every parked connection (their ConnSlots release the
    // tracker), then refuse future adoptions.
    for (fd, _conn) in conns.drain() {
        epoll_del(ep, fd);
    }
    let mut inbox = me.inbox.lock().unwrap();
    inbox.closed = true;
    inbox.queue.clear();
}

/// Register a connection with the shard's epoll instance.
fn register(ep: RawFd, conn: Box<Conn>, conns: &mut HashMap<RawFd, Box<Conn>>) {
    let fd = conn.stream.as_raw_fd();
    let mut ev = sys::EpollEvent {
        events: sys::EPOLLIN | sys::EPOLLRDHUP,
        data: fd as u32 as u64,
    };
    let rc = unsafe { sys::epoll_ctl(ep, sys::EPOLL_CTL_ADD, fd, &mut ev) };
    if rc < 0 {
        return; // dropping the conn closes it and releases the slot
    }
    conns.insert(fd, conn);
}

fn epoll_del(ep: RawFd, fd: RawFd) {
    let rc = unsafe { sys::epoll_ctl(ep, sys::EPOLL_CTL_DEL, fd, std::ptr::null_mut()) };
    debug_assert!(rc == 0, "EPOLL_CTL_DEL on a registered fd cannot fail");
}

/// Drain the socket into the connection's read buffer until it would
/// block, a frame completes, or the peer goes away.
fn pump_read(conn: &mut Conn, max_body: usize) -> Pump {
    loop {
        if conn.rlen == conn.rbuf.len() {
            let cap = frame_cap(max_body);
            if conn.rbuf.len() >= cap {
                // Over-cap frame: by construction the scanner reported
                // Ready before this point; defensively dispatch so the
                // parser can answer rather than spinning here.
                return Pump::Dispatch;
            }
            let grown = (conn.rbuf.len() + READ_CHUNK).min(cap);
            conn.rbuf.resize(grown, 0);
        }
        match conn.stream.read(&mut conn.rbuf[conn.rlen..]) {
            Ok(0) => return Pump::Close,
            Ok(n) => {
                conn.rlen += n;
                if conn.partial_since.is_none() {
                    conn.partial_since = Some(Instant::now());
                }
                if conn.need != 0 {
                    // Header already scanned; just wait out the body.
                    if conn.rlen >= conn.need {
                        return Pump::Dispatch;
                    }
                    continue;
                }
                match http::scan_request_frame(&conn.rbuf[..conn.rlen], max_body) {
                    FrameScan::Ready => return Pump::Dispatch,
                    FrameScan::NeedBody(total) => conn.need = total,
                    FrameScan::Partial => {}
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Pump::Park,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Pump::Close,
        }
    }
}

/// Dispatch worker: serve complete-frame connections through the shared
/// request pipeline, then hand them back to their shard (or close).
fn dispatch_loop(shared: Arc<Shared>, shards: Arc<Vec<Shard>>, pool: Arc<DispatchPool>) {
    loop {
        let conn = {
            let mut q = pool.q.lock().unwrap();
            loop {
                if let Some(c) = q.queue.pop_front() {
                    break Some(c);
                }
                if q.stop {
                    break None;
                }
                q = pool.cv.wait(q).unwrap();
            }
        };
        let Some(conn) = conn else { return };
        serve_conn(&shared, conn, &shards);
    }
}

/// Serve every complete frame buffered on `conn`, then park it back on
/// its shard (keep-alive) or drop it (close). Consumes the connection.
fn serve_conn(shared: &Arc<Shared>, mut conn: Box<Conn>, shards: &[Shard]) {
    let stall = Duration::from_millis(shared.cfg.write_stall_ms);
    let max_body = shared.cfg.max_body_bytes;
    loop {
        let outcome;
        let consumed;
        {
            let Conn {
                rbuf, rlen, bufs, ..
            } = &mut *conn;
            let mut slice: &[u8] = &rbuf[..*rlen];
            let before = slice.len();
            outcome = http::read_request_reusing(&mut slice, max_body, &mut bufs.req);
            consumed = before - slice.len();
        }
        conn.rbuf.copy_within(consumed..conn.rlen, 0);
        conn.rlen -= consumed;
        conn.need = 0;
        match outcome {
            Ok(ScratchOutcome::Request) => {
                let keep;
                {
                    let Conn { stream, bufs, .. } = &mut *conn;
                    let mut w = StallWriter {
                        stream,
                        deadline: Instant::now() + stall,
                    };
                    keep = server::serve_request(shared, bufs, &mut w);
                }
                if !keep {
                    return; // drop: closes the socket, releases the slot
                }
                // Serve pipelined frames already buffered; anything
                // partial goes back to the shard.
                let next = http::scan_request_frame(&conn.rbuf[..conn.rlen], max_body);
                match next {
                    FrameScan::Ready => continue,
                    FrameScan::NeedBody(total) => {
                        conn.need = total;
                        break;
                    }
                    FrameScan::Partial => break,
                }
            }
            Ok(_) => return, // Eof/Idle cannot follow a Ready scan; close
            Err(e) => {
                let Conn { stream, .. } = &mut *conn;
                let mut w = StallWriter {
                    stream,
                    deadline: Instant::now() + stall,
                };
                server::respond_parse_error(shared, &e, &mut w);
                return;
            }
        }
    }
    conn.partial_since = (conn.rlen > 0).then(Instant::now);
    let shard = &shards[conn.shard];
    // `adopt` refusing it (shard exited during drain) drops the conn.
    shard.adopt(conn);
}

/// Bounded writer over a non-blocking socket: optimistic `write`, and on
/// `WouldBlock` a `poll(POLLOUT)` wait against the connection's write
/// deadline. A peer that stops reading gets evicted with `TimedOut`
/// instead of wedging a dispatch worker — the reactor-side fix for the
/// write-stall bug.
struct StallWriter<'a> {
    stream: &'a TcpStream,
    deadline: Instant,
}

impl Write for StallWriter<'_> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        loop {
            let mut sock = self.stream;
            match sock.write(buf) {
                Ok(n) => return Ok(n),
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    let now = Instant::now();
                    if now >= self.deadline {
                        return Err(ErrorKind::TimedOut.into());
                    }
                    let wait = self
                        .deadline
                        .saturating_duration_since(now)
                        .as_millis()
                        .min(i32::MAX as u128) as i32;
                    let mut pfd = sys::PollFd {
                        fd: self.stream.as_raw_fd(),
                        events: sys::POLLOUT,
                        revents: 0,
                    };
                    let rc = unsafe { sys::poll(&mut pfd, 1, wait.max(1)) };
                    if rc < 0 {
                        let err = io::Error::last_os_error();
                        if err.kind() != ErrorKind::Interrupted {
                            return Err(err);
                        }
                    }
                    // rc == 0 (poll timeout) re-checks the deadline above;
                    // rc > 0 retries the write.
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(()) // unbuffered: every write goes straight to the socket
    }
}
