//! Admission control in front of the serving coordinator.
//!
//! Three gates, checked in order at the request edge:
//!
//! 1. **drain** — a gateway that is shutting down sheds everything new
//!    while in-flight work completes;
//! 2. **concurrency** — a global in-flight cap bounds memory and queueing,
//!    shedding with 503;
//! 3. **rate** — a token bucket (refill `rate_rps`, capacity `rate_burst`)
//!    smooths offered load, shedding with 429 + `Retry-After`. Checked
//!    after the cap so capacity-shed requests don't drain the rate budget
//!    of requests that could actually run.
//!
//! A fourth shed source lives past admission: the coordinator's bounded
//! queue ([`crate::coordinator::SubmitError::QueueFull`]), recorded here
//! via [`Admission::note_queue_full`] so `GET /metrics` exposes every shed
//! class side by side.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::GatewayConfig;
use crate::metrics::{Counter, Gauge, Registry};

/// Classic token bucket; `try_acquire` refills lazily from elapsed time.
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

struct BucketState {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// Bucket refilling `rate` tokens/second up to `burst` capacity
    /// (starts full).
    pub fn new(rate: f64, burst: f64) -> TokenBucket {
        TokenBucket {
            rate,
            burst,
            state: Mutex::new(BucketState {
                tokens: burst,
                last: Instant::now(),
            }),
        }
    }

    /// Take one token now if available.
    pub fn try_acquire(&self) -> bool {
        self.try_acquire_at(Instant::now())
    }

    /// Deterministic variant for tests: the caller supplies "now".
    pub fn try_acquire_at(&self, now: Instant) -> bool {
        let mut s = self.state.lock().unwrap();
        if now > s.last {
            let dt = now.duration_since(s.last).as_secs_f64();
            s.tokens = (s.tokens + dt * self.rate).min(self.burst);
            s.last = now;
        }
        if s.tokens >= 1.0 {
            s.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Why a request was shed at the admission edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Token bucket empty — HTTP 429.
    RateLimited,
    /// Global in-flight cap reached — HTTP 503.
    InflightFull,
    /// Gateway is draining for shutdown — HTTP 503.
    Draining,
}

impl AdmitError {
    /// The HTTP status this shed class maps to.
    pub fn status(&self) -> u16 {
        match self {
            AdmitError::RateLimited => 429,
            AdmitError::InflightFull | AdmitError::Draining => 503,
        }
    }

    /// Human-readable shed reason (the response body message).
    pub fn as_str(&self) -> &'static str {
        match self {
            AdmitError::RateLimited => "rate limited",
            AdmitError::InflightFull => "too many in-flight requests",
            AdmitError::Draining => "gateway draining",
        }
    }
}

/// Shared admission state; lives in an `Arc` next to the coordinator.
pub struct Admission {
    bucket: Option<TokenBucket>,
    max_inflight: u64,
    draining: AtomicBool,
    inflight: Arc<Gauge>,
    admitted: Arc<Counter>,
    shed_rate: Arc<Counter>,
    shed_inflight: Arc<Counter>,
    shed_queue: Arc<Counter>,
    shed_drain: Arc<Counter>,
}

impl Admission {
    /// Admission state from the gateway config, instruments registered
    /// in `metrics`.
    pub fn new(cfg: &GatewayConfig, metrics: &Registry) -> Admission {
        Admission {
            bucket: (cfg.rate_rps > 0.0)
                .then(|| TokenBucket::new(cfg.rate_rps, cfg.rate_burst)),
            max_inflight: cfg.max_inflight as u64,
            draining: AtomicBool::new(false),
            inflight: metrics.gauge("gateway.inflight"),
            admitted: metrics.counter("gateway.admitted"),
            shed_rate: metrics.counter("gateway.shed.rate_limited"),
            shed_inflight: metrics.counter("gateway.shed.inflight"),
            shed_queue: metrics.counter("gateway.shed.queue_full"),
            shed_drain: metrics.counter("gateway.shed.draining"),
        }
    }

    /// Admit one request or say why not. The returned permit holds an
    /// in-flight slot until dropped, so callers keep it alive for the
    /// whole submit → response window.
    pub fn try_admit(&self) -> Result<Permit, AdmitError> {
        if self.draining.load(Ordering::Acquire) {
            self.shed_drain.inc();
            return Err(AdmitError::Draining);
        }
        if self.inflight.inc() > self.max_inflight {
            self.inflight.dec();
            self.shed_inflight.inc();
            return Err(AdmitError::InflightFull);
        }
        if let Some(bucket) = &self.bucket {
            if !bucket.try_acquire() {
                self.inflight.dec();
                self.shed_rate.inc();
                return Err(AdmitError::RateLimited);
            }
        }
        self.admitted.inc();
        Ok(Permit {
            inflight: Arc::clone(&self.inflight),
        })
    }

    /// Record a shed caused by the coordinator's bounded queue.
    pub fn note_queue_full(&self) {
        self.shed_queue.inc();
    }

    /// Flip into drain mode: every subsequent admit is refused.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    /// Whether drain mode is active.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Currently admitted (permit-held) request count.
    pub fn inflight(&self) -> u64 {
        self.inflight.get()
    }

    /// The configured in-flight cap (the brownout controller's pressure
    /// denominator).
    pub fn max_inflight(&self) -> u64 {
        self.max_inflight
    }

    /// Total sheds across every class (rate, inflight, queue, drain).
    pub fn shed_total(&self) -> u64 {
        self.shed_rate.get() + self.shed_inflight.get() + self.shed_queue.get()
            + self.shed_drain.get()
    }
}

/// RAII in-flight slot; dropping releases it.
pub struct Permit {
    inflight: Arc<Gauge>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.inflight.dec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cfg(max_inflight: usize, rate_rps: f64, rate_burst: f64) -> GatewayConfig {
        GatewayConfig {
            max_inflight,
            rate_rps,
            rate_burst,
            ..Default::default()
        }
    }

    #[test]
    fn token_bucket_consumes_burst_then_refills() {
        let b = TokenBucket::new(2.0, 3.0);
        let t0 = Instant::now();
        assert!(b.try_acquire_at(t0));
        assert!(b.try_acquire_at(t0));
        assert!(b.try_acquire_at(t0));
        assert!(!b.try_acquire_at(t0), "burst of 3 exhausted");
        // 1 second at 2 rps refills exactly two tokens.
        let t1 = t0 + Duration::from_secs(1);
        assert!(b.try_acquire_at(t1));
        assert!(b.try_acquire_at(t1));
        assert!(!b.try_acquire_at(t1));
    }

    #[test]
    fn token_bucket_caps_refill_at_burst() {
        let b = TokenBucket::new(100.0, 2.0);
        let t0 = Instant::now();
        // A long idle period must not accumulate more than `burst`.
        let t1 = t0 + Duration::from_secs(60);
        assert!(b.try_acquire_at(t1));
        assert!(b.try_acquire_at(t1));
        assert!(!b.try_acquire_at(t1));
    }

    #[test]
    fn inflight_cap_enforced_and_released_by_permit_drop() {
        let metrics = Registry::new();
        let adm = Arc::new(Admission::new(&cfg(2, 0.0, 1.0), &metrics));
        let p1 = adm.try_admit().unwrap();
        let _p2 = adm.try_admit().unwrap();
        assert_eq!(adm.inflight(), 2);
        assert_eq!(adm.try_admit().unwrap_err(), AdmitError::InflightFull);
        assert_eq!(metrics.counter("gateway.shed.inflight").get(), 1);
        drop(p1);
        assert_eq!(adm.inflight(), 1);
        let _p3 = adm.try_admit().unwrap();
        assert_eq!(metrics.counter("gateway.admitted").get(), 3);
    }

    #[test]
    fn rate_limit_sheds_with_429_class() {
        let metrics = Registry::new();
        // rate 0.001 rps, burst 1: the second immediate request is shed.
        let adm = Arc::new(Admission::new(&cfg(16, 0.001, 1.0), &metrics));
        let _p = adm.try_admit().unwrap();
        let err = adm.try_admit().unwrap_err();
        assert_eq!(err, AdmitError::RateLimited);
        assert_eq!(err.status(), 429);
        assert_eq!(metrics.counter("gateway.shed.rate_limited").get(), 1);
    }

    #[test]
    fn draining_refuses_everything_new() {
        let metrics = Registry::new();
        let adm = Arc::new(Admission::new(&cfg(16, 0.0, 1.0), &metrics));
        let _held = adm.try_admit().unwrap();
        adm.begin_drain();
        assert!(adm.is_draining());
        assert_eq!(adm.try_admit().unwrap_err(), AdmitError::Draining);
        assert_eq!(adm.try_admit().unwrap_err().status(), 503);
        // held permit still releases normally
        assert_eq!(adm.inflight(), 1);
    }

    #[test]
    fn queue_full_sheds_are_tallied() {
        let metrics = Registry::new();
        let adm = Arc::new(Admission::new(&cfg(16, 0.0, 1.0), &metrics));
        adm.note_queue_full();
        adm.note_queue_full();
        assert_eq!(metrics.counter("gateway.shed.queue_full").get(), 2);
        assert_eq!(adm.shed_total(), 2);
    }
}
