//! The network gateway: a TCP/HTTP front-end over the model registry.
//!
//! Thread-per-connection accept loop with keep-alive; every inference
//! request passes admission control ([`super::admission`]) before
//! resolving a [`ModelHandle`] and touching that model's coordinator.
//! Endpoints:
//!
//! * `POST /v1/models/{name}/infer` — JSON body `{"features": [f32; N]}`
//!   for one row or `{"rows": [[f32; N], ...]}` for a batch against the
//!   named model (or alias); replies with outputs, the serving model +
//!   version, queue/execute timings and the batch buckets used. Sheds
//!   map to 429/503 with `Retry-After`, coordinator timeouts to 504.
//! * `POST /v1/infer` — same wire format against the registry's default
//!   model (the single-model legacy route).
//! * `GET /v1/models` — registry listing: per-model version, kind,
//!   width, params, in-flight count, aliases and the default marker.
//! * `POST /v1/admin/models/{name}/load` — body `{"path": "m.ckpt"}`
//!   (optional `"version": n`): load or hot-swap a checkpoint manifest.
//!   In-flight requests finish on the old version; new admissions see
//!   the new one (Arc epoch handoff, [`crate::registry`]).
//! * `POST /v1/admin/models/{name}/unload` — remove a model; refused
//!   with 409 while requests are in flight.
//! * `POST /v1/admin/aliases/{alias}` — body `{"target": "name"}`.
//! * `POST /v1/admin/default` — body `{"model": "name"}`.
//! * `POST /v1/models/{name}/train` — start a background training job
//!   toward model `name` ([`crate::trainer`]); body keys (all optional)
//!   override the `[trainer]` defaults: `steps`, `batch`, `lr`,
//!   `momentum`, `lr_decay`, `lr_decay_every`, `width`, `depth`, `rows`,
//!   `noise`, `seed`, `checkpoint_every`, `target_ratio`, `init_mean`,
//!   `init_sigma`, `nonlinear`, `promote` (`"auto"` | `"manual"`).
//! * `GET /v1/jobs` — list training jobs (state, step, loss, lr,
//!   promotions, last checkpoint).
//! * `POST /v1/jobs/{id}/{pause|resume|cancel|promote}` — job controls;
//!   `promote` checkpoints and hot-swaps the job's parameters into the
//!   registry under live traffic.
//! * `GET /healthz` — liveness + drain state + in-flight gauge.
//! * `GET /metrics` — Prometheus text from [`crate::metrics::Registry`]
//!   (gateway + admission + per-model `acdc_model_*` series).
//!
//! The admin surface is unauthenticated by design — deploy it on a
//! trusted network or behind a fronting proxy.
//!
//! Shutdown is a graceful drain: stop accepting, refuse new work at
//! admission, let in-flight requests finish, then wait on a condvar that
//! every connection thread signals on exit — the drain is event-driven
//! (no sleep-polling), bounded by `drain_timeout_ms`.

use std::io::{BufReader, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{Admission, AdmitError};
use super::http::{self, HttpError, ReadOutcome, Request, Response};
use crate::config::{GatewayConfig, TrainerConfig};
use crate::coordinator::SubmitError;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::registry::{ModelHandle, ModelRegistry, RegistryError};
use crate::serve::Server;
use crate::trainer::{JobSpec, JobStatus, TrainerError, TrainerPool};
use crate::util::json::{obj, Json};

/// Poll interval for parked keep-alive connections (also bounds how fast
/// idle connections notice a drain).
const IDLE_POLL: Duration = Duration::from_millis(50);

/// Model name the legacy [`Gateway::start`] constructor registers its
/// server under.
pub const LEGACY_MODEL: &str = "default";

/// Running gateway handle. Dropping it (or calling [`Gateway::shutdown`])
/// drains gracefully.
pub struct Gateway {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

/// Connection-count tracker: the accept-side cap, the exported
/// `gateway.open_connections` gauge, and the event-driven drain barrier —
/// one count, updated in one place. Connection threads signal `cv` on
/// exit, so shutdown blocks on real events instead of sleep-polling.
struct ConnTracker {
    count: Mutex<u64>,
    cv: Condvar,
    /// Prometheus mirror of `count`, kept in lockstep by enter/exit.
    gauge: Arc<Gauge>,
}

impl ConnTracker {
    fn new(gauge: Arc<Gauge>) -> ConnTracker {
        ConnTracker {
            count: Mutex::new(0),
            cv: Condvar::new(),
            gauge,
        }
    }

    /// Claim a connection slot unless the cap is reached.
    fn try_enter(&self, max: u64) -> bool {
        let mut c = self.count.lock().unwrap();
        if *c >= max {
            return false;
        }
        *c += 1;
        self.gauge.set(*c);
        true
    }

    /// Release a slot and wake any drain waiter.
    fn exit(&self) {
        let mut c = self.count.lock().unwrap();
        *c = c.saturating_sub(1);
        self.gauge.set(*c);
        self.cv.notify_all();
    }

    /// Current open-connection count (the `/healthz` reading).
    fn open(&self) -> u64 {
        *self.count.lock().unwrap()
    }

    /// Block until every connection exits or `deadline` passes; returns
    /// whether the count reached zero.
    fn wait_idle(&self, deadline: Instant) -> bool {
        let mut c = self.count.lock().unwrap();
        while *c > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(c, deadline - now).unwrap();
            c = guard;
        }
        true
    }
}

struct Shared {
    registry: Arc<ModelRegistry>,
    trainer: Arc<TrainerPool>,
    cfg: GatewayConfig,
    admission: Arc<Admission>,
    metrics: Arc<Registry>,
    stop: AtomicBool,
    conns: ConnTracker,
    conns_total: Arc<Counter>,
    conns_rejected: Arc<Counter>,
    requests: Arc<Counter>,
    responses_ok: Arc<Counter>,
    http_errors: Arc<Counter>,
    timeouts: Arc<Counter>,
    request_ns: Arc<Histogram>,
}

impl Gateway {
    /// Single-model compatibility constructor: registers `server` in a
    /// fresh registry under [`LEGACY_MODEL`] (which becomes the default
    /// model `POST /v1/infer` routes to) and serves it.
    pub fn start(server: Server, cfg: GatewayConfig) -> Result<Gateway, String> {
        let metrics = Arc::clone(server.metrics());
        let registry = Arc::new(ModelRegistry::new(
            crate::config::ServeConfig::default(),
            metrics,
        ));
        registry
            .insert_server(LEGACY_MODEL, "custom", server, None)
            .map_err(|e| e.to_string())?;
        Gateway::start_registry(registry, cfg)
    }

    /// Bind `cfg.addr` (port 0 for ephemeral) and serve every model in
    /// `registry`. Training jobs submitted over HTTP get a fresh
    /// [`TrainerPool`] with default `[trainer]` knobs; use
    /// [`Gateway::start_registry_with_trainer`] to configure them.
    pub fn start_registry(
        registry: Arc<ModelRegistry>,
        cfg: GatewayConfig,
    ) -> Result<Gateway, String> {
        let trainer = Arc::new(TrainerPool::new(
            Arc::clone(&registry),
            Arc::clone(registry.metrics()),
            TrainerConfig::default(),
        ));
        Gateway::start_registry_with_trainer(registry, trainer, cfg)
    }

    /// [`Gateway::start_registry`] with a caller-configured training-job
    /// pool (the `[trainer]` config section). The pool is drained —
    /// live jobs cancelled and joined — on gateway shutdown.
    pub fn start_registry_with_trainer(
        registry: Arc<ModelRegistry>,
        trainer: Arc<TrainerPool>,
        cfg: GatewayConfig,
    ) -> Result<Gateway, String> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("gateway bind {}: {e}", cfg.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("gateway local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("gateway set_nonblocking: {e}"))?;
        let metrics = Arc::clone(registry.metrics());
        let admission = Arc::new(Admission::new(&cfg, &metrics));
        let shared = Arc::new(Shared {
            registry,
            trainer,
            cfg,
            admission,
            conns: ConnTracker::new(metrics.gauge("gateway.open_connections")),
            conns_total: metrics.counter("gateway.connections"),
            conns_rejected: metrics.counter("gateway.connections_rejected"),
            requests: metrics.counter("gateway.requests"),
            responses_ok: metrics.counter("gateway.responses_ok"),
            http_errors: metrics.counter("gateway.http_errors"),
            timeouts: metrics.counter("gateway.timeouts"),
            request_ns: metrics.histogram("gateway.request_ns"),
            metrics,
            stop: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("acdc-gw-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .map_err(|e| format!("spawn accept loop: {e}"))?;
        Ok(Gateway {
            shared,
            addr,
            accept: Some(accept),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The model registry this gateway serves.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.shared.registry
    }

    /// The training-job pool behind the `/v1/jobs` admin surface.
    pub fn trainer(&self) -> &Arc<TrainerPool> {
        &self.shared.trainer
    }

    /// The shared metrics registry (gateway + registry + coordinators).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.shared.metrics
    }

    /// Text metrics report (the non-Prometheus rendering).
    pub fn metrics_report(&self) -> String {
        self.shared.metrics.report()
    }

    /// Graceful drain, then coordinator teardown. Equivalent to `drop`.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shared.admission.begin_drain();
        self.shared.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Connection threads finish their in-flight request, write the
        // response and signal the tracker on exit (idle connections
        // observe the drain within IDLE_POLL). This wait is event-driven
        // and deterministic: it returns the moment the last connection
        // exits, or at the deadline.
        let deadline = Instant::now() + Duration::from_millis(self.shared.cfg.drain_timeout_ms);
        self.shared.conns.wait_idle(deadline);
        // Training jobs are part of the drain contract: cancel and join
        // them so no background thread outlives the gateway.
        self.shared.trainer.shutdown();
        // Model coordinators drain when the registry's last Arc drops
        // (ours, or a straggler connection past the deadline) — in-flight
        // work is answered either way.
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.conns_total.inc();
                if !shared.conns.try_enter(shared.cfg.max_open_conns as u64) {
                    shared.conns_rejected.inc();
                    reject_connection(stream, shared.cfg.retry_after_s);
                    continue;
                }
                let conn_shared = Arc::clone(&shared);
                let spawned = std::thread::Builder::new()
                    .name("acdc-gw-conn".into())
                    .spawn(move || handle_connection(conn_shared, stream));
                if spawned.is_err() {
                    shared.conns.exit();
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Over the connection cap: answer 503 on the raw socket and close.
fn reject_connection(mut stream: TcpStream, retry_after_s: u64) {
    let _ = stream.set_nonblocking(false);
    let resp = Response::json(503, &err_json("too many connections"))
        .with_header("retry-after", &retry_after_s.to_string());
    let _ = resp.write_to(&mut stream, false);
}

/// Releases the connection slot even if the connection thread unwinds (a
/// leaked slot would wedge admission — and the drain barrier — behind
/// `max_open_conns`).
struct ConnSlot(Arc<Shared>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.conns.exit();
    }
}

fn handle_connection(shared: Arc<Shared>, stream: TcpStream) {
    let _slot = ConnSlot(Arc::clone(&shared));
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    loop {
        match http::read_request(&mut reader, shared.cfg.max_body_bytes) {
            Ok(ReadOutcome::Idle) => {
                if shared.stop.load(Ordering::Acquire) || shared.admission.is_draining() {
                    break;
                }
            }
            Ok(ReadOutcome::Eof) => break,
            Ok(ReadOutcome::Request(req)) => {
                let t0 = Instant::now();
                shared.requests.inc();
                let resp = route(&shared, &req);
                shared.request_ns.record(t0.elapsed());
                if resp.status == 200 {
                    shared.responses_ok.inc();
                }
                let keep = req.wants_keep_alive()
                    && !shared.stop.load(Ordering::Acquire)
                    && !shared.admission.is_draining();
                if resp.write_to(&mut writer, keep).is_err() || !keep {
                    break;
                }
            }
            Err(HttpError::BodyTooLarge(n)) => {
                shared.http_errors.inc();
                let msg = format!("body too large ({n} > {} bytes)", shared.cfg.max_body_bytes);
                let _ = Response::json(413, &err_json(&msg)).write_to(&mut writer, false);
                break;
            }
            Err(HttpError::Malformed(m)) => {
                shared.http_errors.inc();
                let _ = Response::json(400, &err_json(&m)).write_to(&mut writer, false);
                break;
            }
            Err(HttpError::Io(_)) => break,
        }
    }
}

fn route(shared: &Arc<Shared>, req: &Request) -> Response {
    let path = req.route_path();
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => return healthz(shared),
        ("GET", "/metrics") => return Response::text(200, &shared.metrics.prometheus()),
        ("GET", "/v1/models") => return list_models(shared),
        ("POST", "/v1/infer") => return infer(shared, req, None),
        ("GET", "/v1/jobs") => return list_jobs(shared),
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/models") | (_, "/v1/infer")
        | (_, "/v1/jobs") => {
            return Response::json(405, &err_json("method not allowed"));
        }
        _ => {}
    }
    // /v1/models/{name}/infer
    if let Some(name) = path
        .strip_prefix("/v1/models/")
        .and_then(|rest| rest.strip_suffix("/infer"))
    {
        if name.is_empty() || name.contains('/') {
            return Response::json(404, &err_json("not found"));
        }
        if req.method != "POST" {
            return Response::json(405, &err_json("method not allowed"));
        }
        return infer(shared, req, Some(name));
    }
    // /v1/models/{name}/train — submit a background training job
    if let Some(name) = path
        .strip_prefix("/v1/models/")
        .and_then(|rest| rest.strip_suffix("/train"))
    {
        if name.is_empty() || name.contains('/') {
            return Response::json(404, &err_json("not found"));
        }
        if req.method != "POST" {
            return Response::json(405, &err_json("method not allowed"));
        }
        return train_submit(shared, req, name);
    }
    // /v1/jobs/{id}/{pause|resume|cancel|promote}
    if let Some(rest) = path.strip_prefix("/v1/jobs/") {
        if let Some((id_str, action)) = rest.split_once('/') {
            if let Ok(id) = id_str.parse::<u64>() {
                if matches!(action, "pause" | "resume" | "cancel" | "promote") {
                    if req.method != "POST" {
                        return Response::json(405, &err_json("method not allowed"));
                    }
                    return job_action(shared, id, action);
                }
            }
        }
        return Response::json(404, &err_json("not found"));
    }
    // /v1/admin/models/{name}/load | /v1/admin/models/{name}/unload
    if let Some(rest) = path.strip_prefix("/v1/admin/models/") {
        if let Some((name, action)) = rest.rsplit_once('/') {
            if !name.is_empty() && !name.contains('/') && matches!(action, "load" | "unload") {
                if req.method != "POST" {
                    return Response::json(405, &err_json("method not allowed"));
                }
                return match action {
                    "load" => admin_load(shared, req, name),
                    _ => admin_unload(shared, name),
                };
            }
        }
        return Response::json(404, &err_json("not found"));
    }
    // /v1/admin/aliases/{alias}
    if let Some(alias) = path.strip_prefix("/v1/admin/aliases/") {
        if alias.is_empty() || alias.contains('/') {
            return Response::json(404, &err_json("not found"));
        }
        if req.method != "POST" {
            return Response::json(405, &err_json("method not allowed"));
        }
        return admin_alias(shared, req, alias);
    }
    if path == "/v1/admin/default" {
        if req.method != "POST" {
            return Response::json(405, &err_json("method not allowed"));
        }
        return admin_default(shared, req);
    }
    Response::json(404, &err_json("not found"))
}

fn healthz(shared: &Arc<Shared>) -> Response {
    let status = if shared.admission.is_draining() {
        "draining"
    } else {
        "ok"
    };
    let width = match shared.registry.default_width() {
        Some(w) => Json::Num(w as f64),
        None => Json::Null,
    };
    Response::json(
        200,
        &obj(vec![
            ("status", Json::Str(status.to_string())),
            ("width", width),
            ("models", Json::Num(shared.registry.len() as f64)),
            ("inflight", Json::Num(shared.admission.inflight() as f64)),
            (
                "open_connections",
                Json::Num(shared.conns.open() as f64),
            ),
        ]),
    )
}

fn list_models(shared: &Arc<Shared>) -> Response {
    let infos = shared.registry.list();
    let models: Vec<Json> = infos
        .iter()
        .map(|m| {
            obj(vec![
                ("name", Json::Str(m.name.clone())),
                ("version", Json::Num(m.version as f64)),
                ("kind", Json::Str(m.kind.clone())),
                ("width", Json::Num(m.width as f64)),
                ("params", Json::Num(m.params as f64)),
                ("inflight", Json::Num(m.inflight as f64)),
                (
                    "aliases",
                    Json::Arr(m.aliases.iter().cloned().map(Json::Str).collect()),
                ),
                ("default", Json::Bool(m.is_default)),
            ])
        })
        .collect();
    let default = match shared.registry.default_model() {
        Some(name) => Json::Str(name),
        None => Json::Null,
    };
    Response::json(
        200,
        &obj(vec![("models", Json::Arr(models)), ("default", default)]),
    )
}

fn registry_error(e: &RegistryError) -> Response {
    Response::json(e.status(), &err_json(&e.to_string()))
}

fn admin_body(req: &Request) -> Result<Json, Response> {
    let body = std::str::from_utf8(&req.body)
        .map_err(|_| Response::json(400, &err_json("body is not valid utf-8")))?;
    if body.trim().is_empty() {
        return Ok(Json::Obj(Default::default()));
    }
    Json::parse(body).map_err(|e| Response::json(400, &err_json(&format!("bad json: {e}"))))
}

fn admin_load(shared: &Arc<Shared>, req: &Request, name: &str) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(path) = body.get("path").and_then(|p| p.as_str()) else {
        return Response::json(400, &err_json("body must carry a checkpoint 'path'"));
    };
    let version = match body.get("version") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(n) => Some(n as u64),
            None => {
                return Response::json(400, &err_json("'version' must be a non-negative integer"))
            }
        },
    };
    match shared.registry.load_path(name, Path::new(path), version) {
        Ok(v) => Response::json(
            200,
            &obj(vec![
                ("model", Json::Str(name.to_string())),
                ("version", Json::Num(v as f64)),
                ("status", Json::Str("loaded".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn admin_unload(shared: &Arc<Shared>, name: &str) -> Response {
    match shared.registry.unload(name) {
        Ok(()) => Response::json(
            200,
            &obj(vec![
                ("model", Json::Str(name.to_string())),
                ("status", Json::Str("unloaded".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn admin_alias(shared: &Arc<Shared>, req: &Request, alias: &str) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(target) = body.get("target").and_then(|t| t.as_str()) else {
        return Response::json(400, &err_json("body must carry a 'target' model name"));
    };
    match shared.registry.alias(alias, target) {
        Ok(()) => Response::json(
            200,
            &obj(vec![
                ("alias", Json::Str(alias.to_string())),
                ("target", Json::Str(target.to_string())),
                ("status", Json::Str("aliased".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn admin_default(shared: &Arc<Shared>, req: &Request) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(model) = body.get("model").and_then(|m| m.as_str()) else {
        return Response::json(400, &err_json("body must carry a 'model' name"));
    };
    match shared.registry.set_default(model) {
        Ok(()) => Response::json(
            200,
            &obj(vec![
                ("default", Json::Str(model.to_string())),
                ("status", Json::Str("ok".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn trainer_error(e: &TrainerError) -> Response {
    Response::json(e.status(), &err_json(&e.to_string()))
}

/// One `GET /v1/jobs` row.
fn job_json(s: &JobStatus) -> Json {
    let mut pairs = vec![
        ("id", Json::Num(s.id as f64)),
        ("model", Json::Str(s.model.clone())),
        ("state", Json::Str(s.state.as_str().to_string())),
        ("step", Json::Num(s.step as f64)),
        ("steps", Json::Num(s.steps as f64)),
        (
            "loss",
            if s.loss.is_finite() {
                Json::Num(s.loss)
            } else {
                Json::Null
            },
        ),
        (
            "first_loss",
            if s.first_loss.is_finite() {
                Json::Num(s.first_loss)
            } else {
                Json::Null
            },
        ),
        ("lr", Json::Num(s.lr)),
        ("promotions", Json::Num(s.promotions as f64)),
        (
            "promoted_version",
            s.promoted_version.map_or(Json::Null, |v| Json::Num(v as f64)),
        ),
        ("last_checkpoint", s.last_checkpoint.clone().map_or(Json::Null, Json::Str)),
    ];
    if let Some(err) = &s.error {
        pairs.push(("error", Json::Str(err.clone())));
    }
    obj(pairs)
}

fn list_jobs(shared: &Arc<Shared>) -> Response {
    let jobs: Vec<Json> = shared.trainer.list().iter().map(job_json).collect();
    Response::json(200, &obj(vec![("jobs", Json::Arr(jobs))]))
}

/// Build a [`JobSpec`] from the request body: `[trainer]` defaults with
/// any body key overriding. A present-but-mistyped key is a 400.
fn job_spec_from_body(defaults: &JobSpec, body: &Json) -> Result<JobSpec, String> {
    let mut spec = defaults.clone();
    let usize_field = |key: &str, slot: &mut usize| -> Result<(), String> {
        match body.get(key) {
            None => Ok(()),
            Some(v) => match v.as_usize() {
                Some(n) => {
                    *slot = n;
                    Ok(())
                }
                None => Err(format!("'{key}' must be a non-negative integer")),
            },
        }
    };
    let f64_field = |key: &str, slot: &mut f64| -> Result<(), String> {
        match body.get(key) {
            None => Ok(()),
            Some(v) => match v.as_f64() {
                Some(f) => {
                    *slot = f;
                    Ok(())
                }
                None => Err(format!("'{key}' must be a number")),
            },
        }
    };
    usize_field("width", &mut spec.width)?;
    usize_field("depth", &mut spec.depth)?;
    usize_field("steps", &mut spec.steps)?;
    usize_field("batch", &mut spec.batch)?;
    usize_field("rows", &mut spec.dataset_rows)?;
    usize_field("checkpoint_every", &mut spec.checkpoint_every)?;
    usize_field("lr_decay_every", &mut spec.lr_decay_every)?;
    f64_field("lr", &mut spec.lr)?;
    f64_field("momentum", &mut spec.momentum)?;
    f64_field("lr_decay", &mut spec.lr_decay)?;
    f64_field("noise", &mut spec.dataset_noise)?;
    f64_field("target_ratio", &mut spec.target_ratio)?;
    f64_field("init_mean", &mut spec.init.mean)?;
    f64_field("init_sigma", &mut spec.init.sigma)?;
    let mut seed = spec.seed as usize;
    usize_field("seed", &mut seed)?;
    spec.seed = seed as u64;
    match body.get("nonlinear") {
        None => {}
        Some(v) => match v.as_bool() {
            Some(b) => spec.nonlinear = b,
            None => return Err("'nonlinear' must be a boolean".into()),
        },
    }
    match body.get("promote") {
        None => {}
        Some(v) => match v.as_str() {
            Some("auto") => spec.promote_on_complete = true,
            Some("manual") => spec.promote_on_complete = false,
            _ => return Err("'promote' must be \"auto\" or \"manual\"".into()),
        },
    }
    Ok(spec)
}

fn train_submit(shared: &Arc<Shared>, req: &Request, name: &str) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let defaults = JobSpec::from_config(shared.trainer.defaults());
    let spec = match job_spec_from_body(&defaults, &body) {
        Ok(s) => s,
        Err(msg) => return Response::json(400, &err_json(&msg)),
    };
    let steps = spec.steps;
    match shared.trainer.submit(name, spec) {
        Ok(id) => Response::json(
            200,
            &obj(vec![
                ("job", Json::Num(id as f64)),
                ("model", Json::Str(name.to_string())),
                ("steps", Json::Num(steps as f64)),
                ("status", Json::Str("running".to_string())),
            ]),
        ),
        Err(e) => trainer_error(&e),
    }
}

fn job_action(shared: &Arc<Shared>, id: u64, action: &str) -> Response {
    let result = match action {
        "pause" => shared.trainer.pause(id),
        "resume" => shared.trainer.resume(id),
        "cancel" => shared.trainer.cancel(id),
        _ => shared.trainer.promote(id),
    };
    match result {
        Ok(()) => {
            let status = shared
                .trainer
                .status(id)
                .map(|s| job_json(&s))
                .unwrap_or(Json::Null);
            Response::json(
                200,
                &obj(vec![
                    ("job", Json::Num(id as f64)),
                    ("action", Json::Str(action.to_string())),
                    ("status", status),
                ]),
            )
        }
        Err(e) => trainer_error(&e),
    }
}

fn infer(shared: &Arc<Shared>, req: &Request, model: Option<&str>) -> Response {
    // The permit holds an in-flight slot for the whole submit → response
    // window; dropping it on any exit path releases the slot.
    let _permit = match shared.admission.try_admit() {
        Ok(p) => p,
        Err(e) => return shed_response(shared, e),
    };
    // The handle pins this request to one (model, version) epoch: the
    // request survives a concurrent hot swap on the version it was
    // admitted against, and blocks unload until it completes.
    let handle: ModelHandle = match model {
        Some(name) => match shared.registry.resolve(name) {
            Ok(h) => h,
            Err(e) => return registry_error(&e),
        },
        None => match shared.registry.resolve_default() {
            Ok(h) => h,
            Err(e) => return registry_error(&e),
        },
    };
    let body = match std::str::from_utf8(&req.body) {
        Ok(s) => s,
        Err(_) => return Response::json(400, &err_json("body is not valid utf-8")),
    };
    let parsed = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return Response::json(400, &err_json(&format!("bad json: {e}"))),
    };
    let rows = match extract_rows(&parsed, handle.width(), shared.cfg.max_rows_per_request) {
        Ok(rows) => rows,
        Err(msg) => return Response::json(400, &err_json(&msg)),
    };
    let mut rxs = Vec::with_capacity(rows.len());
    for row in rows {
        match handle.submit(row) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::QueueFull) => {
                shared.admission.note_queue_full();
                return shed_retry_after(shared, 503, "coordinator queue full");
            }
            Err(SubmitError::Closed) => {
                return shed_retry_after(shared, 503, "coordinator shutting down");
            }
        }
    }
    // Rows submitted before a mid-batch shed are still answered by the
    // coordinator; their receivers simply drop here.
    let deadline = Instant::now() + Duration::from_millis(shared.cfg.request_timeout_ms);
    let mut outputs = Vec::with_capacity(rxs.len());
    let mut batch_sizes = Vec::with_capacity(rxs.len());
    let mut queue_us = 0u64;
    let mut execute_us = 0u64;
    for rx in rxs {
        let remaining = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(remaining) {
            Ok(resp) => {
                queue_us = queue_us.max(resp.queue_us);
                execute_us = execute_us.max(resp.execute_us);
                batch_sizes.push(Json::Num(resp.batch_size as f64));
                match resp.output {
                    Ok(row) => outputs.push(Json::Arr(
                        row.into_iter().map(|v| Json::Num(v as f64)).collect(),
                    )),
                    Err(e) => {
                        return Response::json(500, &err_json(&format!("executor: {e}")))
                    }
                }
            }
            Err(_) => {
                shared.timeouts.inc();
                return Response::json(504, &err_json("inference timed out"));
            }
        }
    }
    let mut pairs = vec![
        ("model", Json::Str(handle.name().to_string())),
        ("version", Json::Num(handle.version() as f64)),
        ("rows", Json::Num(outputs.len() as f64)),
        ("queue_us", Json::Num(queue_us as f64)),
        ("execute_us", Json::Num(execute_us as f64)),
        ("batch_sizes", Json::Arr(batch_sizes)),
    ];
    if outputs.len() == 1 {
        pairs.push(("output", outputs[0].clone()));
    }
    pairs.push(("outputs", Json::Arr(outputs)));
    Response::json(200, &obj(pairs))
}

/// Feature rows from a request body: `{"features": [...]}` (one row) or
/// `{"rows": [[...], ...]}` (a batch).
fn extract_rows(v: &Json, width: usize, max_rows: usize) -> Result<Vec<Vec<f32>>, String> {
    let parse_row = |arr: &[Json]| -> Result<Vec<f32>, String> {
        if arr.len() != width {
            return Err(format!(
                "row has {} features, model width is {width}",
                arr.len()
            ));
        }
        arr.iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as f32)
                    .filter(|f| f.is_finite())
                    .ok_or_else(|| "features must be finite numbers".to_string())
            })
            .collect()
    };
    if let Some(features) = v.get("features") {
        let arr = features.as_arr().ok_or("'features' must be an array")?;
        return Ok(vec![parse_row(arr)?]);
    }
    if let Some(rows) = v.get("rows") {
        let rows = rows.as_arr().ok_or("'rows' must be an array of arrays")?;
        if rows.is_empty() {
            return Err("'rows' must not be empty".into());
        }
        if rows.len() > max_rows {
            return Err(format!("too many rows ({} > {max_rows})", rows.len()));
        }
        return rows
            .iter()
            .map(|row| parse_row(row.as_arr().ok_or("'rows' must be an array of arrays")?))
            .collect();
    }
    Err("body must carry 'features' (one row) or 'rows' (a batch)".into())
}

fn shed_response(shared: &Arc<Shared>, e: AdmitError) -> Response {
    shed_retry_after(shared, e.status(), e.as_str())
}

fn shed_retry_after(shared: &Arc<Shared>, status: u16, msg: &str) -> Response {
    Response::json(status, &err_json(msg))
        .with_header("retry-after", &shared.cfg.retry_after_s.to_string())
}

fn err_json(msg: &str) -> Json {
    obj(vec![("error", Json::Str(msg.to_string()))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_rows_single_and_batch() {
        let v = Json::parse(r#"{"features": [1.0, 2.0]}"#).unwrap();
        assert_eq!(extract_rows(&v, 2, 8).unwrap(), vec![vec![1.0, 2.0]]);
        let v = Json::parse(r#"{"rows": [[1, 2], [3, 4], [5, 6]]}"#).unwrap();
        assert_eq!(
            extract_rows(&v, 2, 8).unwrap(),
            vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]
        );
    }

    #[test]
    fn extract_rows_validates_width_count_and_values() {
        let v = Json::parse(r#"{"features": [1.0]}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).unwrap_err().contains("width"));
        let v = Json::parse(r#"{"rows": []}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).is_err());
        let v = Json::parse(r#"{"rows": [[1,2],[3,4],[5,6]]}"#).unwrap();
        assert!(extract_rows(&v, 2, 2).unwrap_err().contains("too many"));
        let v = Json::parse(r#"{"features": [1.0, "x"]}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).is_err());
        let v = Json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).is_err());
    }

    #[test]
    fn conn_tracker_caps_counts_and_drains() {
        let gauge = Arc::new(Gauge::default());
        let t = ConnTracker::new(Arc::clone(&gauge));
        assert!(t.try_enter(2));
        assert!(t.try_enter(2));
        assert!(!t.try_enter(2), "cap reached");
        assert_eq!((t.open(), gauge.get()), (2, 2), "gauge mirrors count");
        // Non-blocking drain check fails while connections are open…
        assert!(!t.wait_idle(Instant::now()));
        t.exit();
        t.exit();
        // …and succeeds immediately once they exit.
        assert!(t.wait_idle(Instant::now()));
        assert_eq!((t.open(), gauge.get()), (0, 0));
    }

    #[test]
    fn conn_tracker_wait_wakes_on_exit() {
        let t = Arc::new(ConnTracker::new(Arc::new(Gauge::default())));
        assert!(t.try_enter(8));
        let t2 = Arc::clone(&t);
        let waiter = std::thread::spawn(move || {
            t2.wait_idle(Instant::now() + Duration::from_secs(10))
        });
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        t.exit();
        assert!(waiter.join().unwrap(), "drain must observe the exit");
        // The waiter returned on the notify, far before the 10s deadline.
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
