//! The network gateway: a TCP/HTTP front-end over the model registry.
//!
//! Two interchangeable I/O architectures serve one request pipeline
//! (`gateway.mode`, default `reactor`): the epoll reactor
//! (`super::reactor` — one acceptor, N event-loop shards, a bounded
//! dispatch pool; built for tens of thousands of keep-alive
//! connections) and the thread-per-connection fallback in this module.
//! Both call `serve_request` for every parsed request, so routing,
//! admission, tracing and wire semantics cannot drift between modes.
//! Every inference request passes admission control
//! ([`super::admission`]) before resolving a [`ModelHandle`] and
//! touching that model's coordinator. Endpoints:
//!
//! * `POST /v1/models/{name}/infer` — JSON body `{"features": [f32; N]}`
//!   for one row or `{"rows": [[f32; N], ...]}` for a batch against the
//!   named model (or alias); replies with outputs, the serving model +
//!   version, queue/execute timings and the batch buckets used. Sheds
//!   map to 429/503 with `Retry-After`, coordinator timeouts to 504.
//!   Sending `Content-Type: application/x-acdc-f32` switches request
//!   *and* response bodies to the length-prefixed binary f32 frame
//!   ([`super::wire`]) — bit-identical outputs, no float text on the
//!   wire; errors stay JSON with identical validation wording.
//! * `POST /v1/infer` — same wire format against the registry's default
//!   model (the single-model legacy route).
//! * `GET /v1/models` — registry listing: per-model version, kind,
//!   width, params, in-flight count, aliases and the default marker.
//! * `POST /v1/admin/models/{name}/load` — body `{"path": "m.ckpt"}`
//!   (optional `"version": n`): load or hot-swap a checkpoint manifest.
//!   In-flight requests finish on the old version; new admissions see
//!   the new one (Arc epoch handoff, [`crate::registry`]).
//! * `POST /v1/admin/models/{name}/unload` — remove a model; refused
//!   with 409 while requests are in flight.
//! * `POST /v1/admin/aliases/{alias}` — body `{"target": "name"}`.
//! * `POST /v1/admin/default` — body `{"model": "name"}`.
//! * `POST /v1/models/{name}/train` — start a background training job
//!   toward model `name` ([`crate::trainer`]); body keys (all optional)
//!   override the `[trainer]` defaults: `model_kind` (`"acdc"` |
//!   `"fastfood"` | `"lowrank"` | `"circulant"`), `steps`, `batch`,
//!   `lr`, `momentum`, `lr_decay`, `lr_decay_every`, `width`, `depth`,
//!   `rank`, `rows`, `noise`, `seed`, `checkpoint_every`,
//!   `target_ratio`, `init_mean`, `init_sigma`, `nonlinear`, `promote`
//!   (`"auto"` | `"manual"`).
//! * `GET /v1/jobs` — list training jobs (state, step, loss, lr,
//!   promotions, last checkpoint).
//! * `POST /v1/jobs/{id}/{pause|resume|cancel|promote}` — job controls;
//!   `promote` checkpoints and hot-swaps the job's parameters into the
//!   registry under live traffic.
//! * `GET /healthz` — liveness + drain state + in-flight gauge.
//! * `GET /metrics` — Prometheus text from [`crate::metrics::Registry`]
//!   (gateway + admission + per-model `acdc_model_*` series, plus the
//!   per-stage `acdc_trace_*_ns` pipeline histograms).
//! * `GET /v1/debug/slow` — the slow-request ring ([`crate::trace`]):
//!   per-stage latency breakdowns of recent requests over the `[trace]`
//!   threshold, newest first (followed live by `acdc tail`).
//!
//! Every sampled inference request (all of them at the default
//! `sample_every = 1`) carries an `x-trace-id` response header; sending
//! `X-Acdc-Debug: 1` returns the stage breakdown inline in the response
//! body. Span records live in the per-connection arena and the slow ring
//! is lock-free, so tracing on by default preserves the zero-allocation
//! steady state (`tests/zero_alloc.rs`).
//!
//! The admin surface is unauthenticated by design — deploy it on a
//! trusted network or behind a fronting proxy.
//!
//! Shutdown is a graceful drain: stop accepting, refuse new work at
//! admission, let in-flight requests finish (the reactor additionally
//! closes parked idle connections and joins its shard/dispatch
//! threads), then wait on a condvar that every connection signals on
//! exit — the drain is event-driven (no sleep-polling), bounded by
//! `drain_timeout_ms`.

use std::io::{BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{Admission, AdmitError};
use super::brownout::{self, Brownout};
use super::http::{self, HttpError, RequestScratch, Response, ScratchOutcome};
use super::reactor::Reactor;
use super::wire;
use crate::cluster::RouterCore;
use crate::config::{ClusterConfig, GatewayConfig, GatewayMode, TrainerConfig};
use crate::coordinator::request::{ResponseSlot, RowRef, SlotError};
use crate::coordinator::SubmitError;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::registry::{ModelHandle, ModelInfo, ModelRegistry, RegistryError};
use crate::sell::ModelKind;
use crate::serve::Server;
use crate::trace::log::{self, Field, Level};
use crate::trace::{self, SlowRing, SpanRecord, Stage};
use crate::trainer::{JobSpec, JobStatus, TrainerError, TrainerPool};
use crate::util::json::{obj, Json};

/// Poll interval for parked keep-alive connections (also bounds how fast
/// idle connections notice a drain).
const IDLE_POLL: Duration = Duration::from_millis(50);

/// Model name the legacy [`Gateway::start`] constructor registers its
/// server under.
pub const LEGACY_MODEL: &str = "default";

/// Running gateway handle. Dropping it (or calling [`Gateway::shutdown`])
/// drains gracefully.
pub struct Gateway {
    shared: Arc<Shared>,
    addr: SocketAddr,
    /// Threaded-mode acceptor thread (`None` in reactor mode).
    accept: Option<JoinHandle<()>>,
    /// Reactor-mode event machinery (`None` in threaded mode).
    reactor: Option<Reactor>,
    /// Brownout controller thread (`None` when `[brownout]` is disabled).
    brownout_ctl: Option<brownout::Controller>,
}

/// Connection-count tracker: the accept-side cap, the exported
/// `gateway.open_connections` gauge, and the event-driven drain barrier —
/// one count, updated in one place. Connection threads signal `cv` on
/// exit, so shutdown blocks on real events instead of sleep-polling.
pub(super) struct ConnTracker {
    count: Mutex<u64>,
    cv: Condvar,
    /// Prometheus mirror of `count`, kept in lockstep by enter/exit.
    gauge: Arc<Gauge>,
}

impl ConnTracker {
    fn new(gauge: Arc<Gauge>) -> ConnTracker {
        ConnTracker {
            count: Mutex::new(0),
            cv: Condvar::new(),
            gauge,
        }
    }

    /// Claim a connection slot unless the cap is reached.
    pub(super) fn try_enter(&self, max: u64) -> bool {
        let mut c = self.count.lock().unwrap();
        if *c >= max {
            return false;
        }
        *c += 1;
        self.gauge.set(*c);
        true
    }

    /// Release a slot and wake any drain waiter.
    fn exit(&self) {
        let mut c = self.count.lock().unwrap();
        *c = c.saturating_sub(1);
        self.gauge.set(*c);
        self.cv.notify_all();
    }

    /// Current open-connection count (the `/healthz` reading).
    fn open(&self) -> u64 {
        *self.count.lock().unwrap()
    }

    /// Block until every connection exits or `deadline` passes; returns
    /// whether the count reached zero.
    fn wait_idle(&self, deadline: Instant) -> bool {
        let mut c = self.count.lock().unwrap();
        while *c > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(c, deadline.saturating_duration_since(now))
                .unwrap();
            c = guard;
        }
        true
    }
}

pub(super) struct Shared {
    registry: Arc<ModelRegistry>,
    trainer: Arc<TrainerPool>,
    pub(super) cfg: GatewayConfig,
    pub(super) admission: Arc<Admission>,
    metrics: Arc<Registry>,
    pub(super) stop: AtomicBool,
    pub(super) conns: ConnTracker,
    pub(super) conns_total: Arc<Counter>,
    pub(super) conns_rejected: Arc<Counter>,
    requests: Arc<Counter>,
    responses_ok: Arc<Counter>,
    http_errors: Arc<Counter>,
    timeouts: Arc<Counter>,
    request_ns: Arc<Histogram>,
    /// Bounded capture of requests over the `[trace]` slow threshold,
    /// served by `GET /v1/debug/slow` and followed by `acdc tail`.
    slow_ring: Arc<SlowRing>,
    /// Request counter driving `trace.sample_every` (1 = trace all).
    trace_seq: AtomicU64,
    /// Per-stage latency histograms (`trace.{stage}_ns`), cached at
    /// startup so recording a span is pure atomics — indexed by
    /// [`Stage::index`].
    stage_ns: [Arc<Histogram>; Stage::COUNT],
    /// Cluster router core when this gateway runs the router role
    /// (`None` on shards and standalone gateways). With a router
    /// present, inference routes are proxied to upstream shards instead
    /// of the local registry — on both I/O modes, since the reactor's
    /// dispatch workers and the threaded fallback share `serve_request`.
    router: Option<Arc<RouterCore>>,
    /// Brownout ladder state, read on every request (level + effective
    /// trace sampling stride); driven by the controller thread.
    brownout: Arc<Brownout>,
}

impl Gateway {
    /// Single-model compatibility constructor: registers `server` in a
    /// fresh registry under [`LEGACY_MODEL`] (which becomes the default
    /// model `POST /v1/infer` routes to) and serves it.
    pub fn start(server: Server, cfg: GatewayConfig) -> Result<Gateway, String> {
        let metrics = Arc::clone(server.metrics());
        let registry = Arc::new(ModelRegistry::new(
            crate::config::ServeConfig::default(),
            metrics,
        ));
        registry
            .insert_server(LEGACY_MODEL, "custom", server, None)
            .map_err(|e| e.to_string())?;
        Gateway::start_registry(registry, cfg)
    }

    /// Bind `cfg.addr` (port 0 for ephemeral) and serve every model in
    /// `registry`. Training jobs submitted over HTTP get a fresh
    /// [`TrainerPool`] with default `[trainer]` knobs; use
    /// [`Gateway::start_registry_with_trainer`] to configure them.
    pub fn start_registry(
        registry: Arc<ModelRegistry>,
        cfg: GatewayConfig,
    ) -> Result<Gateway, String> {
        let trainer = Arc::new(TrainerPool::new(
            Arc::clone(&registry),
            Arc::clone(registry.metrics()),
            TrainerConfig::default(),
        ));
        Gateway::start_registry_with_trainer(registry, trainer, cfg)
    }

    /// [`Gateway::start_registry`] with a caller-configured training-job
    /// pool (the `[trainer]` config section). The pool is drained —
    /// live jobs cancelled and joined — on gateway shutdown.
    pub fn start_registry_with_trainer(
        registry: Arc<ModelRegistry>,
        trainer: Arc<TrainerPool>,
        cfg: GatewayConfig,
    ) -> Result<Gateway, String> {
        Gateway::start_inner(registry, trainer, cfg, None)
    }

    /// Start the cluster **router** role: a gateway whose inference
    /// routes are proxied across the `[cluster]` shard topology (ring
    /// placement, replication, health-checked retry, hedging) instead of
    /// a local registry. The admin surface gains `GET /v1/cluster` and
    /// the rolling swap at `POST /v1/admin/cluster/models/{name}/load`;
    /// the local registry stays empty, so shard-only admin routes answer
    /// 404/"not found" as they would on a modelless gateway.
    pub fn start_router(cluster: ClusterConfig, cfg: GatewayConfig) -> Result<Gateway, String> {
        let metrics = Arc::new(Registry::new());
        let router = RouterCore::start(cluster, &metrics)?;
        let registry = Arc::new(ModelRegistry::new(
            crate::config::ServeConfig::default(),
            Arc::clone(&metrics),
        ));
        let trainer = Arc::new(TrainerPool::new(
            Arc::clone(&registry),
            metrics,
            TrainerConfig::default(),
        ));
        Gateway::start_inner(registry, trainer, cfg, Some(router))
    }

    /// Shared constructor behind every public `start_*`: bind, build the
    /// [`Shared`] state (with or without a router core), and launch the
    /// configured I/O mode.
    fn start_inner(
        registry: Arc<ModelRegistry>,
        trainer: Arc<TrainerPool>,
        cfg: GatewayConfig,
        router: Option<Arc<RouterCore>>,
    ) -> Result<Gateway, String> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("gateway bind {}: {e}", cfg.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("gateway local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("gateway set_nonblocking: {e}"))?;
        let metrics = Arc::clone(registry.metrics());
        let admission = Arc::new(Admission::new(&cfg, &metrics));
        // Logger knobs come from the `[trace]` section; ACDC_LOG overrides
        // the level inside init.
        log::init(
            Level::parse(&cfg.trace.log_level).unwrap_or(Level::Info),
            cfg.trace.log_max_per_s,
        );
        let slow_ring = Arc::new(SlowRing::new(
            cfg.trace.ring_capacity,
            Duration::from_millis(cfg.trace.slow_ms),
        ));
        let stage_ns = Stage::ALL.map(|s| metrics.histogram(&format!("trace.{}_ns", s.name())));
        let brownout_state = Arc::new(Brownout::new(
            cfg.trace.sample_every.max(1),
            cfg.brownout.sample_coarsen,
            &metrics,
        ));
        let shared = Arc::new(Shared {
            registry,
            trainer,
            cfg,
            admission,
            conns: ConnTracker::new(metrics.gauge("gateway.open_connections")),
            conns_total: metrics.counter("gateway.connections"),
            conns_rejected: metrics.counter("gateway.connections_rejected"),
            requests: metrics.counter("gateway.requests"),
            responses_ok: metrics.counter("gateway.responses_ok"),
            http_errors: metrics.counter("gateway.http_errors"),
            timeouts: metrics.counter("gateway.timeouts"),
            request_ns: metrics.histogram("gateway.request_ns"),
            slow_ring,
            trace_seq: AtomicU64::new(0),
            stage_ns,
            router,
            brownout: Arc::clone(&brownout_state),
            metrics,
            stop: AtomicBool::new(false),
        });
        let brownout_ctl = if shared.cfg.brownout.enabled {
            Some(brownout::Controller::start(
                shared.cfg.brownout.clone(),
                brownout_state,
                Arc::clone(&shared.admission),
                shared.metrics.gauge("coordinator.queue_depth"),
                shared.router.clone(),
            )?)
        } else {
            None
        };
        let mode = shared.cfg.resolved_mode();
        let addr_str = addr.to_string();
        log::event(
            Level::Info,
            "gateway",
            "listening",
            0,
            &[
                ("addr", Field::Str(&addr_str)),
                ("mode", Field::Str(mode.name())),
                ("slow_ms", Field::U64(shared.cfg.trace.slow_ms)),
                ("ring_capacity", Field::U64(shared.cfg.trace.ring_capacity as u64)),
            ],
        );
        let (accept, reactor) = match mode {
            GatewayMode::Reactor => {
                let r = Reactor::start(Arc::clone(&shared), listener)?;
                (None, Some(r))
            }
            GatewayMode::Threaded => {
                let accept_shared = Arc::clone(&shared);
                let h = std::thread::Builder::new()
                    .name("acdc-gw-accept".into())
                    .spawn(move || accept_loop(listener, accept_shared))
                    .map_err(|e| format!("spawn accept loop: {e}"))?;
                (Some(h), None)
            }
        };
        Ok(Gateway {
            shared,
            addr,
            accept,
            reactor,
            brownout_ctl,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The model registry this gateway serves.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.shared.registry
    }

    /// The training-job pool behind the `/v1/jobs` admin surface.
    pub fn trainer(&self) -> &Arc<TrainerPool> {
        &self.shared.trainer
    }

    /// The shared metrics registry (gateway + registry + coordinators).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.shared.metrics
    }

    /// Text metrics report (the non-Prometheus rendering).
    pub fn metrics_report(&self) -> String {
        self.shared.metrics.report()
    }

    /// Graceful drain, then coordinator teardown. Equivalent to `drop`.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shared.admission.begin_drain();
        self.shared.stop.store(true, Ordering::Release);
        // The brownout controller reads gauges other subsystems own;
        // stop it first so teardown order cannot race a tick.
        if let Some(mut ctl) = self.brownout_ctl.take() {
            ctl.shutdown();
        }
        log::event(
            Level::Info,
            "gateway",
            "drain_begin",
            0,
            &[("open_connections", Field::U64(self.shared.conns.open()))],
        );
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(r) = self.reactor.take() {
            // The reactor owns its connections: shutdown wakes every
            // shard and dispatch worker, closes parked idle connections,
            // lets in-flight requests finish (bounded by the request and
            // write-stall deadlines) and joins the threads — every
            // tracker slot is released on return, so the wait below is
            // immediate in reactor mode.
            r.shutdown();
        }
        // Connection threads finish their in-flight request, write the
        // response and signal the tracker on exit (idle connections
        // observe the drain within IDLE_POLL). This wait is event-driven
        // and deterministic: it returns the moment the last connection
        // exits, or at the deadline.
        let deadline = Instant::now() + Duration::from_millis(self.shared.cfg.drain_timeout_ms);
        let drained = self.shared.conns.wait_idle(deadline);
        log::event(
            Level::Info,
            "gateway",
            "drain_complete",
            0,
            &[("clean", Field::Bool(drained))],
        );
        // Training jobs are part of the drain contract: cancel and join
        // them so no background thread outlives the gateway.
        self.shared.trainer.shutdown();
        // On the router role, stop and join the health prober too.
        if let Some(router) = &self.shared.router {
            router.shutdown();
        }
        // Model coordinators drain when the registry's last Arc drops
        // (ours, or a straggler connection past the deadline) — in-flight
        // work is answered either way.
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.conns_total.inc();
                if !shared.conns.try_enter(shared.cfg.max_open_conns as u64) {
                    shared.conns_rejected.inc();
                    reject_connection(stream, shared.cfg.retry_after_s);
                    continue;
                }
                let conn_shared = Arc::clone(&shared);
                let spawned = std::thread::Builder::new()
                    .name("acdc-gw-conn".into())
                    .spawn(move || handle_connection(conn_shared, stream));
                if spawned.is_err() {
                    shared.conns.exit();
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Over the connection cap: answer 503 on the raw socket and close.
pub(super) fn reject_connection(mut stream: TcpStream, retry_after_s: u64) {
    let _ = stream.set_nonblocking(false);
    let resp = Response::json(503, &err_json("too many connections"))
        .with_header("retry-after", &retry_after_s.to_string());
    let _ = resp.write_to(&mut stream, false);
}

/// Releases the connection slot even if the connection thread unwinds (a
/// leaked slot would wedge admission — and the drain barrier — behind
/// `max_open_conns`).
pub(super) struct ConnSlot(pub(super) Arc<Shared>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.conns.exit();
    }
}

/// All reusable per-connection buffers: HTTP parse scratch, the inference
/// arena, and the response head/body write buffers. Everything grows to
/// the connection's request shape once and is then reused — the basis of
/// the zero-allocation steady state (pinned by `tests/zero_alloc.rs`).
pub(super) struct ConnBufs {
    /// HTTP request parse scratch (the reactor's dispatch workers parse
    /// into this from the connection's accumulated read buffer).
    pub(super) req: RequestScratch,
    arena: InferArena,
    head: Vec<u8>,
    body: Vec<u8>,
}

impl ConnBufs {
    pub(super) fn new() -> ConnBufs {
        ConnBufs {
            req: RequestScratch::new(),
            arena: InferArena::default(),
            head: Vec::new(),
            body: Vec::new(),
        }
    }
}

/// The connection-owned inference arena: flat `[rows × width]` input and
/// output buffers, plus the reusable completion slots and per-row
/// metadata. Workers copy rows in/out of `rows`/`outs` under the slot
/// protocol ([`crate::coordinator::request::ResponseSlot`]).
#[derive(Default)]
struct InferArena {
    /// Row-major `[rows, width]` parsed input features.
    rows: Vec<f32>,
    /// Row-major `[rows, width]` output destination (stride = width).
    outs: Vec<f32>,
    /// Reusable completion slots, one per concurrent row of one request.
    slots: Vec<Arc<ResponseSlot>>,
    /// Sequence numbers of the current request's slot uses.
    seqs: Vec<u64>,
    /// Output row lengths (≤ width) of the current request.
    out_lens: Vec<usize>,
    /// Batch bucket each row was served in.
    batch_sizes: Vec<usize>,
    /// The current request's span record — arena-resident so tracing
    /// being on by default performs no per-request allocation.
    span: SpanRecord,
}

impl InferArena {
    /// Grow (never shrink) the output/metadata buffers for a request of
    /// `rows` rows of `width` features. Called before any slot is issued,
    /// so no outstanding [`RowRef`] can observe a reallocation.
    fn ensure(&mut self, rows: usize, width: usize) {
        let need = rows * width;
        if self.outs.len() < need {
            self.outs.resize(need, 0.0);
        }
        while self.slots.len() < rows {
            self.slots.push(Arc::new(ResponseSlot::new()));
        }
        if self.seqs.len() < rows {
            self.seqs.resize(rows, 0);
        }
        if self.out_lens.len() < rows {
            self.out_lens.resize(rows, 0);
        }
        if self.batch_sizes.len() < rows {
            self.batch_sizes.resize(rows, 0);
        }
    }
}

/// Abandons every issued slot use on drop, so no exit path (timeout,
/// shed, executor error, panic) can leave a worker holding live pointers
/// into an arena the connection is about to reuse. Abandoning a completed
/// use is a no-op, so the guard is safe to drop on success too.
struct SlotReaper<'a> {
    slots: &'a [Arc<ResponseSlot>],
    seqs: &'a [u64],
    count: usize,
}

impl Drop for SlotReaper<'_> {
    fn drop(&mut self) {
        for r in 0..self.count {
            self.slots[r].abandon(self.seqs[r]);
        }
    }
}

/// The `{name}` of a well-formed `/v1/models/{name}/infer` path — the
/// single source of the model-name rules shared by the fast-path
/// interceptor and `route`'s 404/405 leftovers.
fn infer_model_name(path: &str) -> Option<&str> {
    path.strip_prefix("/v1/models/")
        .and_then(|rest| rest.strip_suffix("/infer"))
        .filter(|name| !name.is_empty() && !name.contains('/'))
}

/// If `method`/`path` is an inference POST, the (optional) model name:
/// `Some(None)` = default-model `/v1/infer`, `Some(Some(name))` = the
/// per-model route. These run on the streaming fast path, not `route`.
fn infer_route<'a>(method: &str, path: &'a str) -> Option<Option<&'a str>> {
    if method != "POST" {
        return None;
    }
    if path == "/v1/infer" {
        return Some(None);
    }
    infer_model_name(path).map(Some)
}

fn handle_connection(shared: Arc<Shared>, stream: TcpStream) {
    let _slot = ConnSlot(Arc::clone(&shared));
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    // A peer that stops reading must not wedge this thread in `write_all`
    // forever: bound blocking writes the same way the reactor's
    // poll-based writer bounds its non-blocking ones.
    let _ = stream.set_write_timeout(Some(Duration::from_millis(shared.cfg.write_stall_ms)));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    let mut bufs = ConnBufs::new();
    loop {
        match http::read_request_reusing(&mut reader, shared.cfg.max_body_bytes, &mut bufs.req) {
            Ok(ScratchOutcome::Idle) => {
                if shared.stop.load(Ordering::Acquire) || shared.admission.is_draining() {
                    break;
                }
            }
            Ok(ScratchOutcome::Eof) => break,
            Ok(ScratchOutcome::Request) => {
                if !serve_request(&shared, &mut bufs, &mut writer) {
                    break;
                }
            }
            Err(e) => {
                respond_parse_error(&shared, &e, &mut writer);
                break;
            }
        }
    }
}

/// Serve the request currently parsed into `bufs.req`, writing the
/// response through `writer`; returns whether the connection should be
/// kept open. This is the single request pipeline shared verbatim by the
/// threaded fallback path and the reactor's dispatch workers, so wire
/// semantics cannot drift between the two gateway modes.
pub(super) fn serve_request<W: Write>(
    shared: &Arc<Shared>,
    bufs: &mut ConnBufs,
    writer: &mut W,
) -> bool {
    let ConnBufs {
        req,
        arena,
        head,
        body,
    } = bufs;
    let t0 = Instant::now();
    shared.requests.inc();
    let keep = req.wants_keep_alive()
        && !shared.stop.load(Ordering::Acquire)
        && !shared.admission.is_draining();
    // Brownout top rung: everything but the health/observability surface
    // is shed before any routing or parsing work is spent on it.
    if shared.brownout.level() >= brownout::LEVEL_SHED_ALL
        && !matches!(req.route_path(), "/healthz" | "/metrics")
    {
        shared.brownout.note_shed();
        let resp = shed_retry_after(shared, 503, "brownout: shedding non-health traffic");
        shared.request_ns.record(t0.elapsed());
        return resp.write_to(writer, keep).is_ok() && keep;
    }
    if let Some(model) = infer_route(&req.method, req.route_path()) {
        // Router role: inference routes are forwarded to upstream shards
        // (the body travels byte-for-byte, so the binary f32 frame needs
        // no reparsing here). Everything else still routes locally.
        if shared.router.is_some() {
            return proxy_infer(shared, req, model, &mut arena.span, writer, t0, keep);
        }
        // Streaming fast path: parse into the arena, serve through the
        // slot protocol, serialize straight into the connection's write
        // buffers — no allocation after warmup. `Content-Type:
        // application/x-acdc-f32` selects the binary f32 frame for both
        // directions.
        let binary = wire::is_binary_content_type(req.header("content-type").unwrap_or(""));
        match infer(shared, req, model, arena, body, binary) {
            Ok(()) => {
                shared.responses_ok.inc();
                let content_type = if binary {
                    wire::CONTENT_TYPE
                } else {
                    "application/json"
                };
                if arena.span.trace_id != 0 {
                    http::write_head_with_trace(
                        head,
                        200,
                        content_type,
                        body.len(),
                        keep,
                        arena.span.trace_id,
                    );
                } else {
                    http::write_head(head, 200, content_type, body.len(), keep);
                }
                shared.request_ns.record(t0.elapsed());
                let w0 = Instant::now();
                let wrote = writer
                    .write_all(head)
                    .and_then(|()| writer.write_all(body))
                    .and_then(|()| writer.flush());
                arena.span.set(Stage::Write, w0.elapsed());
                finish_span(shared, &mut arena.span, 200, t0.elapsed());
                wrote.is_ok() && keep
            }
            Err(resp) => {
                shared.request_ns.record(t0.elapsed());
                let resp = if arena.span.trace_id != 0 {
                    resp.with_header("x-trace-id", &format!("{:016x}", arena.span.trace_id))
                } else {
                    resp
                };
                let status = resp.status;
                let write_ok = resp.write_to(writer, keep).is_ok();
                finish_span(shared, &mut arena.span, status, t0.elapsed());
                write_ok && keep
            }
        }
    } else {
        let resp = route(shared, req);
        shared.request_ns.record(t0.elapsed());
        if resp.status == 200 {
            shared.responses_ok.inc();
        }
        resp.write_to(writer, keep).is_ok() && keep
    }
}

/// Answer a request-parse error on `writer`. Parse errors always close
/// the connection (the stream position is indeterminate), so there is no
/// keep-alive verdict to return. Shared by both gateway modes.
pub(super) fn respond_parse_error<W: Write>(shared: &Arc<Shared>, e: &HttpError, writer: &mut W) {
    match e {
        HttpError::BodyTooLarge(n) => {
            shared.http_errors.inc();
            let msg = format!("body too large ({n} > {} bytes)", shared.cfg.max_body_bytes);
            let _ = Response::json(413, &err_json(&msg)).write_to(writer, false);
        }
        HttpError::Malformed(m) => {
            shared.http_errors.inc();
            let _ = Response::json(400, &err_json(m)).write_to(writer, false);
        }
        HttpError::Io(_) => {}
    }
}

/// Serve one inference request on the router role: admit, place by model
/// name on the ring, and forward through [`RouterCore::proxy`] (retry +
/// hedging live there). The upstream's body travels byte-for-byte in both
/// directions — JSON and the binary f32 frame proxy identically — and the
/// winning shard's topology index is echoed as `x-acdc-upstream`. Returns
/// the keep-alive verdict, mirroring the local fast path.
fn proxy_infer<W: Write>(
    shared: &Arc<Shared>,
    req: &RequestScratch,
    model: Option<&str>,
    span: &mut SpanRecord,
    writer: &mut W,
    t0: Instant,
    keep: bool,
) -> bool {
    span.reset();
    if shared.cfg.trace.enabled {
        let seq = shared.trace_seq.fetch_add(1, Ordering::Relaxed);
        if seq % shared.brownout.effective_sample_every() == 0 {
            span.trace_id = trace::mint_trace_id();
        }
    }
    let a0 = Instant::now();
    let resp = match deadline_budget_ms(shared, req) {
        Err(resp) => {
            shared.http_errors.inc();
            resp
        }
        Ok(budget_ms) => match shared.admission.try_admit() {
            Err(e) => {
                log::event(
                    Level::Debug,
                    "gateway",
                    "request_shed",
                    span.trace_id,
                    &[("reason", Field::Str(e.as_str()))],
                );
                shed_response(shared, e)
            }
            // The permit holds an in-flight slot for the whole upstream
            // exchange; it drops when this arm's response is built.
            Ok(_permit) => {
                span.set(Stage::Admission, a0.elapsed());
                let key = model.unwrap_or(LEGACY_MODEL);
                let content_type = req.header("content-type").unwrap_or("application/json");
                let router = shared.router.as_ref().expect("proxy_infer requires a router");
                let u0 = Instant::now();
                let result = router.proxy(
                    key,
                    req.route_path(),
                    content_type,
                    &req.body,
                    Duration::from_millis(budget_ms),
                );
                span.set(Stage::Upstream, u0.elapsed());
                match result {
                    Ok(reply) => {
                        let mut resp = Response {
                            status: reply.status,
                            headers: vec![("content-type".into(), reply.content_type)],
                            body: reply.body,
                        }
                        .with_header("x-acdc-upstream", &reply.upstream.to_string());
                        if reply.hedged {
                            resp = resp.with_header("x-acdc-hedged", "1");
                        }
                        resp
                    }
                    Err((status, msg)) => {
                        if status == 504 {
                            shared.timeouts.inc();
                        } else {
                            shared.http_errors.inc();
                        }
                        let resp = Response::json(status, &err_json(&msg));
                        if matches!(status, 503 | 504) {
                            // Router-level shed/timeout: tell the client
                            // when to come back, like the local path.
                            resp.with_header(
                                "retry-after",
                                &shared.cfg.retry_after_s.to_string(),
                            )
                        } else {
                            resp
                        }
                    }
                }
            }
        },
    };
    let status = resp.status;
    if status == 200 {
        shared.responses_ok.inc();
    }
    shared.request_ns.record(t0.elapsed());
    let resp = if span.trace_id != 0 {
        resp.with_header("x-trace-id", &format!("{:016x}", span.trace_id))
    } else {
        resp
    };
    let w0 = Instant::now();
    let write_ok = resp.write_to(writer, keep).is_ok();
    span.set(Stage::Write, w0.elapsed());
    finish_span(shared, span, status, t0.elapsed());
    write_ok && keep
}

fn route(shared: &Arc<Shared>, req: &RequestScratch) -> Response {
    let path = req.route_path();
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => return healthz(shared),
        ("GET", "/metrics") => return Response::text(200, &shared.metrics.prometheus()),
        ("GET", "/v1/models") => return list_models(shared),
        ("GET", "/v1/jobs") => return list_jobs(shared),
        ("GET", "/v1/debug/slow") => return debug_slow(shared),
        ("GET", "/v1/cluster") => return cluster_topology(shared),
        // POST /v1/infer is served on the streaming fast path before
        // `route`; everything landing here is a bad method.
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/models") | (_, "/v1/infer")
        | (_, "/v1/jobs") | (_, "/v1/debug/slow") | (_, "/v1/cluster") => {
            return Response::json(405, &err_json("method not allowed"));
        }
        _ => {}
    }
    // /v1/models/{name}/infer — POST with a valid name is intercepted on
    // the streaming fast path; here only bad names / bad methods remain.
    if let Some(rest) = path.strip_prefix("/v1/models/") {
        if rest.strip_suffix("/infer").is_some() {
            return if infer_model_name(path).is_some() {
                Response::json(405, &err_json("method not allowed"))
            } else {
                Response::json(404, &err_json("not found"))
            };
        }
    }
    // /v1/models/{name}/train — submit a background training job
    if let Some(name) = path
        .strip_prefix("/v1/models/")
        .and_then(|rest| rest.strip_suffix("/train"))
    {
        if name.is_empty() || name.contains('/') {
            return Response::json(404, &err_json("not found"));
        }
        if req.method != "POST" {
            return Response::json(405, &err_json("method not allowed"));
        }
        return train_submit(shared, req, name);
    }
    // /v1/models/{name} — single-model snapshot. The cluster router
    // polls this during a rolling swap: the `inflight` field reaching
    // zero is the drain signal for the replica being upgraded.
    if let Some(name) = path.strip_prefix("/v1/models/") {
        if !name.is_empty() && !name.contains('/') {
            if req.method != "GET" {
                return Response::json(405, &err_json("method not allowed"));
            }
            return match shared.registry.info(name) {
                Some(m) => Response::json(200, &model_json(&m)),
                None => Response::json(404, &err_json(&format!("model '{name}' not found"))),
            };
        }
    }
    // /v1/jobs/{id}/{pause|resume|cancel|promote}
    if let Some(rest) = path.strip_prefix("/v1/jobs/") {
        if let Some((id_str, action)) = rest.split_once('/') {
            if let Ok(id) = id_str.parse::<u64>() {
                if matches!(action, "pause" | "resume" | "cancel" | "promote") {
                    if req.method != "POST" {
                        return Response::json(405, &err_json("method not allowed"));
                    }
                    return job_action(shared, id, action);
                }
            }
        }
        return Response::json(404, &err_json("not found"));
    }
    // /v1/admin/models/{name}/load | /v1/admin/models/{name}/unload
    if let Some(rest) = path.strip_prefix("/v1/admin/models/") {
        if let Some((name, action)) = rest.rsplit_once('/') {
            if !name.is_empty() && !name.contains('/') && matches!(action, "load" | "unload") {
                if req.method != "POST" {
                    return Response::json(405, &err_json("method not allowed"));
                }
                return match action {
                    "load" => admin_load(shared, req, name),
                    _ => admin_unload(shared, name),
                };
            }
        }
        return Response::json(404, &err_json("not found"));
    }
    // /v1/admin/cluster/models/{name}/load — router-only rolling swap:
    // drain and upgrade one replica at a time across the model's ring
    // placement (404 on shards and standalone gateways).
    if let Some(rest) = path.strip_prefix("/v1/admin/cluster/models/") {
        if let Some(name) = rest.strip_suffix("/load") {
            if !name.is_empty() && !name.contains('/') {
                if req.method != "POST" {
                    return Response::json(405, &err_json("method not allowed"));
                }
                return cluster_load(shared, req, name);
            }
        }
        return Response::json(404, &err_json("not found"));
    }
    // /v1/admin/aliases/{alias}
    if let Some(alias) = path.strip_prefix("/v1/admin/aliases/") {
        if alias.is_empty() || alias.contains('/') {
            return Response::json(404, &err_json("not found"));
        }
        if req.method != "POST" {
            return Response::json(405, &err_json("method not allowed"));
        }
        return admin_alias(shared, req, alias);
    }
    if path == "/v1/admin/default" {
        if req.method != "POST" {
            return Response::json(405, &err_json("method not allowed"));
        }
        return admin_default(shared, req);
    }
    Response::json(404, &err_json("not found"))
}

fn healthz(shared: &Arc<Shared>) -> Response {
    let status = if shared.admission.is_draining() {
        "draining"
    } else {
        "ok"
    };
    let width = match shared.registry.default_width() {
        Some(w) => Json::Num(w as f64),
        None => Json::Null,
    };
    Response::json(
        200,
        &obj(vec![
            ("status", Json::Str(status.to_string())),
            ("width", width),
            ("models", Json::Num(shared.registry.len() as f64)),
            ("inflight", Json::Num(shared.admission.inflight() as f64)),
            (
                "open_connections",
                Json::Num(shared.conns.open() as f64),
            ),
        ]),
    )
}

/// `GET /v1/debug/slow` — the slow-request ring, newest first. A debug
/// surface: allocation here is fine, only the capture path is hot.
fn debug_slow(shared: &Arc<Shared>) -> Response {
    let entries: Vec<Json> = shared
        .slow_ring
        .snapshot()
        .iter()
        .map(|rec| {
            let stages = Json::Obj(
                Stage::ALL
                    .iter()
                    .map(|s| {
                        (
                            format!("{}_us", s.name()),
                            Json::Num((rec.get(*s) / 1_000) as f64),
                        )
                    })
                    .collect(),
            );
            obj(vec![
                ("trace_id", Json::Str(format!("{:016x}", rec.trace_id))),
                ("total_us", Json::Num((rec.total_ns / 1_000) as f64)),
                ("status", Json::Num(rec.status as f64)),
                ("rows", Json::Num(rec.rows as f64)),
                ("batch_size", Json::Num(rec.batch as f64)),
                ("unix_ms", Json::Num(rec.unix_ms as f64)),
                ("slowest", Json::Str(rec.slowest().name().to_string())),
                ("stages", stages),
            ])
        })
        .collect();
    Response::json(
        200,
        &obj(vec![
            (
                "threshold_us",
                Json::Num((shared.slow_ring.threshold_ns() / 1_000) as f64),
            ),
            (
                "capacity",
                Json::Num(shared.slow_ring.capacity() as f64),
            ),
            ("recorded", Json::Num(shared.slow_ring.recorded() as f64)),
            ("entries", Json::Arr(entries)),
        ]),
    )
}

/// One model's JSON rendering, shared by `GET /v1/models` and the
/// single-model `GET /v1/models/{name}` route.
fn model_json(m: &ModelInfo) -> Json {
    obj(vec![
        ("name", Json::Str(m.name.clone())),
        ("version", Json::Num(m.version as f64)),
        ("kind", Json::Str(m.kind.clone())),
        ("width", Json::Num(m.width as f64)),
        ("params", Json::Num(m.params as f64)),
        ("inflight", Json::Num(m.inflight as f64)),
        (
            "aliases",
            Json::Arr(m.aliases.iter().cloned().map(Json::Str).collect()),
        ),
        ("default", Json::Bool(m.is_default)),
    ])
}

fn list_models(shared: &Arc<Shared>) -> Response {
    let infos = shared.registry.list();
    let models: Vec<Json> = infos.iter().map(model_json).collect();
    let default = match shared.registry.default_model() {
        Some(name) => Json::Str(name),
        None => Json::Null,
    };
    Response::json(
        200,
        &obj(vec![("models", Json::Arr(models)), ("default", default)]),
    )
}

/// `GET /v1/cluster` — topology + live health snapshot on the router
/// role; 404 elsewhere (a shard has no cluster view).
fn cluster_topology(shared: &Arc<Shared>) -> Response {
    match &shared.router {
        Some(router) => Response::json(200, &router.topology_json()),
        None => Response::json(404, &err_json("not a cluster router")),
    }
}

/// `POST /v1/admin/cluster/models/{name}/load` — the cluster-wide
/// rolling swap. Body matches the shard-local load (`{"path": ...,
/// "version"?: n}`); the router drains and upgrades each replica of
/// `name` in ring order under live traffic.
fn cluster_load(shared: &Arc<Shared>, req: &RequestScratch, name: &str) -> Response {
    let Some(router) = &shared.router else {
        return Response::json(404, &err_json("not a cluster router"));
    };
    let body = match admin_body(req) {
        Ok(b) => b,
        Err(resp) => return resp,
    };
    let Some(path) = body.get("path").and_then(|p| p.as_str()) else {
        return Response::json(400, &err_json("body must carry a checkpoint 'path'"));
    };
    let version = match body.get("version") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(n) => Some(n as u64),
            None => {
                return Response::json(400, &err_json("'version' must be a non-negative integer"))
            }
        },
    };
    match router.rolling_swap(name, path, version) {
        Ok(report) => Response::json(200, &report),
        Err((status, msg)) => Response::json(status, &err_json(&msg)),
    }
}

fn registry_error(e: &RegistryError) -> Response {
    Response::json(e.status(), &err_json(&e.to_string()))
}

fn admin_body(req: &RequestScratch) -> Result<Json, Response> {
    let body = std::str::from_utf8(&req.body)
        .map_err(|_| Response::json(400, &err_json("body is not valid utf-8")))?;
    if body.trim().is_empty() {
        return Ok(Json::Obj(Default::default()));
    }
    Json::parse(body).map_err(|e| Response::json(400, &err_json(&format!("bad json: {e}"))))
}

fn admin_load(shared: &Arc<Shared>, req: &RequestScratch, name: &str) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(path) = body.get("path").and_then(|p| p.as_str()) else {
        return Response::json(400, &err_json("body must carry a checkpoint 'path'"));
    };
    let version = match body.get("version") {
        None => None,
        Some(v) => match v.as_usize() {
            Some(n) => Some(n as u64),
            None => {
                return Response::json(400, &err_json("'version' must be a non-negative integer"))
            }
        },
    };
    match shared.registry.load_path(name, Path::new(path), version) {
        Ok(v) => Response::json(
            200,
            &obj(vec![
                ("model", Json::Str(name.to_string())),
                ("version", Json::Num(v as f64)),
                ("status", Json::Str("loaded".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn admin_unload(shared: &Arc<Shared>, name: &str) -> Response {
    match shared.registry.unload(name) {
        Ok(()) => Response::json(
            200,
            &obj(vec![
                ("model", Json::Str(name.to_string())),
                ("status", Json::Str("unloaded".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn admin_alias(shared: &Arc<Shared>, req: &RequestScratch, alias: &str) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(target) = body.get("target").and_then(|t| t.as_str()) else {
        return Response::json(400, &err_json("body must carry a 'target' model name"));
    };
    match shared.registry.alias(alias, target) {
        Ok(()) => Response::json(
            200,
            &obj(vec![
                ("alias", Json::Str(alias.to_string())),
                ("target", Json::Str(target.to_string())),
                ("status", Json::Str("aliased".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn admin_default(shared: &Arc<Shared>, req: &RequestScratch) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let Some(model) = body.get("model").and_then(|m| m.as_str()) else {
        return Response::json(400, &err_json("body must carry a 'model' name"));
    };
    match shared.registry.set_default(model) {
        Ok(()) => Response::json(
            200,
            &obj(vec![
                ("default", Json::Str(model.to_string())),
                ("status", Json::Str("ok".to_string())),
            ]),
        ),
        Err(e) => registry_error(&e),
    }
}

fn trainer_error(e: &TrainerError) -> Response {
    Response::json(e.status(), &err_json(&e.to_string()))
}

/// One `GET /v1/jobs` row.
fn job_json(s: &JobStatus) -> Json {
    let mut pairs = vec![
        ("id", Json::Num(s.id as f64)),
        ("model", Json::Str(s.model.clone())),
        ("state", Json::Str(s.state.as_str().to_string())),
        ("step", Json::Num(s.step as f64)),
        ("steps", Json::Num(s.steps as f64)),
        (
            "loss",
            if s.loss.is_finite() {
                Json::Num(s.loss)
            } else {
                Json::Null
            },
        ),
        (
            "first_loss",
            if s.first_loss.is_finite() {
                Json::Num(s.first_loss)
            } else {
                Json::Null
            },
        ),
        ("lr", Json::Num(s.lr)),
        ("promotions", Json::Num(s.promotions as f64)),
        (
            "promoted_version",
            s.promoted_version.map_or(Json::Null, |v| Json::Num(v as f64)),
        ),
        ("last_checkpoint", s.last_checkpoint.clone().map_or(Json::Null, Json::Str)),
    ];
    if let Some(err) = &s.error {
        pairs.push(("error", Json::Str(err.clone())));
    }
    obj(pairs)
}

fn list_jobs(shared: &Arc<Shared>) -> Response {
    let jobs: Vec<Json> = shared.trainer.list().iter().map(job_json).collect();
    Response::json(200, &obj(vec![("jobs", Json::Arr(jobs))]))
}

/// Build a [`JobSpec`] from the request body: `[trainer]` defaults with
/// any body key overriding. A present-but-mistyped key is a 400.
fn job_spec_from_body(defaults: &JobSpec, body: &Json) -> Result<JobSpec, String> {
    let mut spec = defaults.clone();
    let usize_field = |key: &str, slot: &mut usize| -> Result<(), String> {
        match body.get(key) {
            None => Ok(()),
            Some(v) => match v.as_usize() {
                Some(n) => {
                    *slot = n;
                    Ok(())
                }
                None => Err(format!("'{key}' must be a non-negative integer")),
            },
        }
    };
    let f64_field = |key: &str, slot: &mut f64| -> Result<(), String> {
        match body.get(key) {
            None => Ok(()),
            Some(v) => match v.as_f64() {
                Some(f) => {
                    *slot = f;
                    Ok(())
                }
                None => Err(format!("'{key}' must be a number")),
            },
        }
    };
    usize_field("width", &mut spec.width)?;
    usize_field("depth", &mut spec.depth)?;
    usize_field("rank", &mut spec.rank)?;
    usize_field("steps", &mut spec.steps)?;
    usize_field("batch", &mut spec.batch)?;
    usize_field("rows", &mut spec.dataset_rows)?;
    usize_field("checkpoint_every", &mut spec.checkpoint_every)?;
    usize_field("lr_decay_every", &mut spec.lr_decay_every)?;
    f64_field("lr", &mut spec.lr)?;
    f64_field("momentum", &mut spec.momentum)?;
    f64_field("lr_decay", &mut spec.lr_decay)?;
    f64_field("noise", &mut spec.dataset_noise)?;
    f64_field("target_ratio", &mut spec.target_ratio)?;
    f64_field("init_mean", &mut spec.init.mean)?;
    f64_field("init_sigma", &mut spec.init.sigma)?;
    let mut seed = spec.seed as usize;
    usize_field("seed", &mut seed)?;
    spec.seed = seed as u64;
    match body.get("model_kind") {
        None => {}
        Some(v) => match v.as_str().and_then(ModelKind::parse) {
            Some(k) => spec.model_kind = k,
            None => {
                return Err(
                    "'model_kind' must be one of acdc, fastfood, lowrank, circulant".into(),
                )
            }
        },
    }
    match body.get("nonlinear") {
        None => {}
        Some(v) => match v.as_bool() {
            Some(b) => spec.nonlinear = b,
            None => return Err("'nonlinear' must be a boolean".into()),
        },
    }
    match body.get("promote") {
        None => {}
        Some(v) => match v.as_str() {
            Some("auto") => spec.promote_on_complete = true,
            Some("manual") => spec.promote_on_complete = false,
            _ => return Err("'promote' must be \"auto\" or \"manual\"".into()),
        },
    }
    Ok(spec)
}

fn train_submit(shared: &Arc<Shared>, req: &RequestScratch, name: &str) -> Response {
    let body = match admin_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let defaults = JobSpec::from_config(shared.trainer.defaults());
    let spec = match job_spec_from_body(&defaults, &body) {
        Ok(s) => s,
        Err(msg) => return Response::json(400, &err_json(&msg)),
    };
    let steps = spec.steps;
    match shared.trainer.submit(name, spec) {
        Ok(id) => Response::json(
            200,
            &obj(vec![
                ("job", Json::Num(id as f64)),
                ("model", Json::Str(name.to_string())),
                ("steps", Json::Num(steps as f64)),
                ("status", Json::Str("running".to_string())),
            ]),
        ),
        Err(e) => trainer_error(&e),
    }
}

fn job_action(shared: &Arc<Shared>, id: u64, action: &str) -> Response {
    let result = match action {
        "pause" => shared.trainer.pause(id),
        "resume" => shared.trainer.resume(id),
        "cancel" => shared.trainer.cancel(id),
        _ => shared.trainer.promote(id),
    };
    match result {
        Ok(()) => {
            let status = shared
                .trainer
                .status(id)
                .map(|s| job_json(&s))
                .unwrap_or(Json::Null);
            Response::json(
                200,
                &obj(vec![
                    ("job", Json::Num(id as f64)),
                    ("action", Json::Str(action.to_string())),
                    ("status", status),
                ]),
            )
        }
        Err(e) => trainer_error(&e),
    }
}

/// Serve one inference request on the zero-allocation streaming path.
///
/// Flow: admission permit → epoch handle → parse the body straight into
/// the connection arena (specialized scanner; non-canonical bodies fall
/// back to the DOM parser) → issue slot sequences → submit borrowed rows
/// → wait on the slots → serialize floats directly into the connection's
/// write buffer. On success `body_out` holds the complete response body
/// (JSON, or the binary f32 frame when `binary` is set) and nothing was
/// heap-allocated (after warmup); on failure the returned [`Response`]
/// carries the error exactly as the legacy path did — errors are always
/// JSON, with identical wording on both wire formats.
fn infer(
    shared: &Arc<Shared>,
    req: &RequestScratch,
    model: Option<&str>,
    arena: &mut InferArena,
    body_out: &mut Vec<u8>,
    binary: bool,
) -> Result<(), Response> {
    // Span setup: reset the arena-resident record and mint a trace ID for
    // sampled requests (every request at the default `sample_every = 1`).
    // Both are allocation-free, preserving the zero-allocation invariant
    // with tracing on by default.
    arena.span.reset();
    if shared.cfg.trace.enabled {
        let seq = shared.trace_seq.fetch_add(1, Ordering::Relaxed);
        // The stride is the configured `trace.sample_every` until
        // brownout level 2 coarsens it.
        if seq % shared.brownout.effective_sample_every() == 0 {
            arena.span.trace_id = trace::mint_trace_id();
        }
    }
    // Deadline mint: the header-requested budget clamped by `[limits]`
    // (or the default). Parsed before admission so a malformed header
    // costs a 400, not an admission permit.
    let budget_ms = deadline_budget_ms(shared, req)?;
    // The permit holds an in-flight slot for the whole submit → response
    // window; dropping it on any exit path releases the slot.
    let a0 = Instant::now();
    let _permit = shared.admission.try_admit().map_err(|e| {
        log::event(
            Level::Debug,
            "gateway",
            "request_shed",
            arena.span.trace_id,
            &[("reason", Field::Str(e.as_str()))],
        );
        shed_response(shared, e)
    })?;
    let t0 = Instant::now();
    // The deadline is fixed at admission and travels with every row
    // through batcher and worker; each downstream stage re-checks it
    // rather than computing work no one is waiting for.
    let deadline = t0 + Duration::from_millis(budget_ms);
    // The handle pins this request to one (model, version) epoch: the
    // request survives a concurrent hot swap on the version it was
    // admitted against, and blocks unload until it completes.
    let handle: ModelHandle = match model {
        Some(name) => shared.registry.resolve(name),
        None => shared.registry.resolve_default(),
    }
    .map_err(|e| registry_error(&e))?;
    // Admission covers the gate (permit) plus model/epoch resolution.
    arena.span.set(Stage::Admission, a0.elapsed());
    let width = handle.width();
    let p0 = Instant::now();
    let rows = if binary {
        // Binary frame: raw little-endian f32 rows, no float text parsing
        // or UTF-8 requirement. Validation wording is pinned to the JSON
        // path's exactly ([`wire::parse_binary_request`]).
        wire::parse_binary_request(
            &req.body,
            width,
            shared.cfg.max_rows_per_request,
            &mut arena.rows,
        )
        .map_err(|msg| Response::json(400, &err_json(&msg)))?
    } else {
        let body = std::str::from_utf8(&req.body)
            .map_err(|_| Response::json(400, &err_json("body is not valid utf-8")))?;
        match parse_infer_fast(body, width, shared.cfg.max_rows_per_request, &mut arena.rows) {
            Ok(Some(rows)) => rows,
            Ok(None) => {
                // Non-canonical body (extra keys, odd spacing, bad
                // numbers): the DOM parser preserves the legacy
                // validation semantics.
                let parsed = Json::parse(body)
                    .map_err(|e| Response::json(400, &err_json(&format!("bad json: {e}"))))?;
                extract_rows_dom(&parsed, width, shared.cfg.max_rows_per_request, &mut arena.rows)
                    .map_err(|msg| Response::json(400, &err_json(&msg)))?
            }
            Err(msg) => return Err(Response::json(400, &err_json(&msg))),
        }
    };
    arena.span.set(Stage::Parse, p0.elapsed());
    arena.span.rows = rows as u32;
    // Brownout level 3+: multi-row requests are the largest unit of
    // executor work — shed them and keep single-row traffic answering.
    if rows > 1 && shared.brownout.level() >= brownout::LEVEL_SHED_BATCH {
        shared.brownout.note_shed();
        return Err(shed_retry_after(
            shared,
            503,
            "brownout: shedding batch requests",
        ));
    }
    debug_assert_eq!(arena.rows.len(), rows * width);
    // Grow the output arena and slot pool *before* issuing any sequence,
    // so no outstanding RowRef can observe a reallocation.
    arena.ensure(rows, width);
    for r in 0..rows {
        arena.seqs[r] = arena.slots[r].issue();
    }
    // From here on every exit path runs the reaper, so no worker can
    // touch the arena after this function returns.
    let reaper = SlotReaper {
        slots: &arena.slots,
        seqs: &arena.seqs,
        count: rows,
    };
    for r in 0..rows {
        // SAFETY: the input/output regions live in the connection arena,
        // are disjoint per row (stride = width), and stay untouched until
        // the slot use is observed done or the reaper abandons it.
        let row = unsafe {
            RowRef::new(
                arena.rows.as_ptr().add(r * width),
                width,
                arena.outs.as_mut_ptr().add(r * width),
                width,
                arena.seqs[r],
            )
        };
        match handle.submit_slot(row, &arena.slots[r], arena.span.trace_id, Some(deadline)) {
            Ok(()) => {}
            Err(SubmitError::QueueFull) => {
                shared.admission.note_queue_full();
                return Err(shed_retry_after(shared, 503, "coordinator queue full"));
            }
            Err(SubmitError::Closed) => {
                return Err(shed_retry_after(shared, 503, "coordinator shutting down"));
            }
        }
    }
    // Rows submitted before a mid-batch shed are abandoned by the reaper;
    // the workers then skip them without touching the arena. The slot
    // wait honors whichever bound is tighter: the request's own deadline
    // or the gateway-wide `request_timeout_ms` backstop.
    let wait_deadline =
        deadline.min(Instant::now() + Duration::from_millis(shared.cfg.request_timeout_ms));
    let mut queue_us = 0u64;
    let mut form_us = 0u64;
    let mut execute_us = 0u64;
    let mut max_batch = 0usize;
    for r in 0..rows {
        match arena.slots[r].wait(arena.seqs[r], wait_deadline) {
            Some(reply) => {
                queue_us = queue_us.max(reply.queue_us);
                form_us = form_us.max(reply.form_us);
                execute_us = execute_us.max(reply.execute_us);
                max_batch = max_batch.max(reply.batch_size);
                arena.batch_sizes[r] = reply.batch_size;
                match reply.output {
                    Ok(len) => arena.out_lens[r] = len,
                    Err(SlotError::Expired) => {
                        // The pipeline reaped this row (batcher or
                        // worker); a typed 504, not an executor 500.
                        shared.timeouts.inc();
                        return Err(shed_retry_after(shared, 504, "deadline exceeded"));
                    }
                    Err(SlotError::Exec(e)) => {
                        return Err(Response::json(500, &err_json(&format!("executor: {e}"))))
                    }
                }
            }
            None => {
                shared.timeouts.inc();
                return Err(shed_retry_after(shared, 504, "inference timed out"));
            }
        }
    }
    // Pipeline stages measured off-thread travel back on the slot replies
    // (maxima across the request's rows).
    arena.span.set(Stage::QueueWait, Duration::from_micros(queue_us));
    arena.span.set(Stage::BatchForm, Duration::from_micros(form_us));
    arena.span.set(Stage::Execute, Duration::from_micros(execute_us));
    arena.span.batch = max_batch as u32;
    // All rows completed — reaping is now a no-op; drop the guard so the
    // serializer below can borrow the arena freely.
    drop(reaper);
    handle.observe_request(t0.elapsed());
    // Opt-in inline breakdown: `X-Acdc-Debug: 1` adds a "trace" object to
    // the response body (serialize/write aren't finished yet, so those two
    // stages appear only in the ring and the /metrics histograms). The
    // binary frame has no trace field; use the JSON path to debug.
    let debug_breakdown =
        !binary && arena.span.trace_id != 0 && req.header("x-acdc-debug") == Some("1");
    let s0 = Instant::now();
    if binary {
        wire::write_binary_response(
            body_out,
            rows,
            width,
            handle.version(),
            queue_us,
            execute_us,
            &arena.outs,
            &arena.out_lens,
        );
    } else {
        write_infer_body(
            body_out,
            handle.name(),
            handle.version(),
            rows,
            width,
            queue_us,
            execute_us,
            arena,
            debug_breakdown,
        );
    }
    arena.span.set(Stage::Serialize, s0.elapsed());
    Ok(())
}

/// Close out a request's span on the connection thread: record every
/// stage into the `trace.{stage}_ns` histograms (successes only, so error
/// zeros don't skew the series), publish to the slow ring when the total
/// crossed the threshold, and emit the request-scoped log event.
fn finish_span(shared: &Arc<Shared>, span: &mut SpanRecord, status: u16, total: Duration) {
    if span.trace_id == 0 {
        return; // tracing disabled or request sampled out
    }
    span.total_ns = total.as_nanos() as u64;
    span.status = status;
    if status == 200 {
        for s in Stage::ALL {
            shared.stage_ns[s.index()].record_ns(span.get(s));
        }
    }
    if span.total_ns >= shared.slow_ring.threshold_ns() {
        span.unix_ms = trace::unix_ms();
        shared.slow_ring.record(span);
        log::event(
            Level::Warn,
            "gateway",
            "slow_request",
            span.trace_id,
            &[
                ("total_us", Field::U64(span.total_ns / 1_000)),
                ("status", Field::U64(status as u64)),
                ("slowest", Field::Str(span.slowest().name())),
                (
                    "slowest_us",
                    Field::U64(span.get(span.slowest()) / 1_000),
                ),
            ],
        );
    } else if log::enabled(Level::Debug) {
        log::event(
            Level::Debug,
            "gateway",
            "request_done",
            span.trace_id,
            &[
                ("total_us", Field::U64(span.total_ns / 1_000)),
                ("status", Field::U64(status as u64)),
            ],
        );
    }
}

/// Specialized scanner for the canonical inference bodies
/// (`{"features": [...]}` / `{"rows": [[...], ...]}`): parses the floats
/// straight into `out` with zero allocation. Returns `Ok(None)` when the
/// body deviates from the canonical shape — the caller then falls back to
/// the DOM parser, which preserves the legacy validation semantics
/// (extra keys, duplicate keys, overflow literals, trailing garbage).
fn parse_infer_fast(
    body: &str,
    width: usize,
    max_rows: usize,
    out: &mut Vec<f32>,
) -> Result<Option<usize>, String> {
    let b = body.as_bytes();
    let mut i = 0usize;
    out.clear();
    skip_ws(b, &mut i);
    if next_byte(b, &mut i) != Some(b'{') {
        return Ok(None);
    }
    skip_ws(b, &mut i);
    let Some(key) = scan_plain_key(b, &mut i) else {
        return Ok(None);
    };
    skip_ws(b, &mut i);
    if next_byte(b, &mut i) != Some(b':') {
        return Ok(None);
    }
    skip_ws(b, &mut i);
    let rows = if key == b"features" {
        match scan_num_row(b, &mut i, width, out)? {
            Some(()) => 1,
            None => return Ok(None),
        }
    } else if key == b"rows" {
        if next_byte(b, &mut i) != Some(b'[') {
            return Ok(None);
        }
        skip_ws(b, &mut i);
        if peek_byte(b, i) == Some(b']') {
            return Err("'rows' must not be empty".into());
        }
        let mut rows = 0usize;
        loop {
            if scan_num_row(b, &mut i, width, out)?.is_none() {
                return Ok(None);
            }
            rows += 1;
            if rows > max_rows {
                // The DOM path reports the exact count; counting the
                // remainder here just to echo it back is not worth it.
                return Err(format!("too many rows ({rows}+ > {max_rows})"));
            }
            skip_ws(b, &mut i);
            match next_byte(b, &mut i) {
                Some(b',') => skip_ws(b, &mut i),
                Some(b']') => break,
                _ => return Ok(None),
            }
        }
        rows
    } else {
        return Ok(None);
    };
    skip_ws(b, &mut i);
    if next_byte(b, &mut i) != Some(b'}') {
        return Ok(None);
    }
    skip_ws(b, &mut i);
    if i != b.len() {
        return Ok(None);
    }
    Ok(Some(rows))
}

#[inline]
fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

#[inline]
fn peek_byte(b: &[u8], i: usize) -> Option<u8> {
    b.get(i).copied()
}

#[inline]
fn next_byte(b: &[u8], i: &mut usize) -> Option<u8> {
    let v = b.get(*i).copied();
    if v.is_some() {
        *i += 1;
    }
    v
}

/// A quoted key with no escapes; returns the raw bytes between quotes.
fn scan_plain_key<'a>(b: &'a [u8], i: &mut usize) -> Option<&'a [u8]> {
    if next_byte(b, i) != Some(b'"') {
        return None;
    }
    let start = *i;
    while let Some(c) = peek_byte(b, *i) {
        match c {
            b'"' => {
                let key = &b[start..*i];
                *i += 1;
                return Some(key);
            }
            b'\\' => return None, // escapes → DOM fallback
            _ => *i += 1,
        }
    }
    None
}

/// One `[num, num, ...]` row of exactly `width` finite numbers, appended
/// to `out`. `Ok(None)` = not canonical (fall back to the DOM parser,
/// which also owns the overflow/NaN error wording); `Err` = definitively
/// invalid with the legacy message.
fn scan_num_row(
    b: &[u8],
    i: &mut usize,
    width: usize,
    out: &mut Vec<f32>,
) -> Result<Option<()>, String> {
    if next_byte(b, i) != Some(b'[') {
        return Ok(None);
    }
    let row_start = out.len();
    let mut count = 0usize;
    skip_ws(b, i);
    if peek_byte(b, *i) == Some(b']') {
        *i += 1;
        return Err(format!("row has 0 features, model width is {width}"));
    }
    loop {
        skip_ws(b, i);
        let start = *i;
        while let Some(c) = peek_byte(b, *i) {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                *i += 1;
            } else {
                break;
            }
        }
        if start == *i || !is_json_number(&b[start..*i]) {
            // Not a strict JSON number literal (strings, null, "+1", "1.",
            // leading zeros, …) — the DOM parser owns those verdicts.
            out.truncate(row_start);
            return Ok(None);
        }
        // This slice is ASCII by construction.
        let text = std::str::from_utf8(&b[start..*i]).unwrap_or("");
        let Ok(v) = text.parse::<f64>() else {
            out.truncate(row_start);
            return Ok(None);
        };
        if !v.is_finite() {
            // Overflow literals ("1e999"): let the DOM parser reject with
            // the canonical "number out of range" wording.
            out.truncate(row_start);
            return Ok(None);
        }
        count += 1;
        if count <= width {
            out.push(v as f32);
        }
        skip_ws(b, i);
        match next_byte(b, i) {
            Some(b',') => {}
            Some(b']') => break,
            _ => {
                out.truncate(row_start);
                return Ok(None);
            }
        }
    }
    if count != width {
        out.truncate(row_start);
        return Err(format!(
            "row has {count} features, model width is {width}"
        ));
    }
    Ok(Some(()))
}

/// Strict JSON number grammar check
/// (`-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?`) — keeps the fast
/// scanner exactly as strict as [`Json::parse`], falling anything laxer
/// back to the DOM.
fn is_json_number(t: &[u8]) -> bool {
    let mut i = 0usize;
    if t.first() == Some(&b'-') {
        i += 1;
    }
    match t.get(i) {
        Some(b'0') => i += 1,
        Some(c) if c.is_ascii_digit() => {
            while t.get(i).is_some_and(|c| c.is_ascii_digit()) {
                i += 1;
            }
        }
        _ => return false,
    }
    if t.get(i) == Some(&b'.') {
        i += 1;
        let s = i;
        while t.get(i).is_some_and(|c| c.is_ascii_digit()) {
            i += 1;
        }
        if i == s {
            return false;
        }
    }
    if matches!(t.get(i), Some(b'e' | b'E')) {
        i += 1;
        if matches!(t.get(i), Some(b'+' | b'-')) {
            i += 1;
        }
        let s = i;
        while t.get(i).is_some_and(|c| c.is_ascii_digit()) {
            i += 1;
        }
        if i == s {
            return false;
        }
    }
    i == t.len()
}

/// Feature rows from an already-parsed body into the flat arena buffer:
/// `{"features": [...]}` (one row) or `{"rows": [[...], ...]}` (a batch).
/// The DOM fallback of [`parse_infer_fast`] — preserves the legacy
/// validation wording exactly.
fn extract_rows_dom(
    v: &Json,
    width: usize,
    max_rows: usize,
    out: &mut Vec<f32>,
) -> Result<usize, String> {
    out.clear();
    let mut push_row = |arr: &[Json], out: &mut Vec<f32>| -> Result<(), String> {
        if arr.len() != width {
            return Err(format!(
                "row has {} features, model width is {width}",
                arr.len()
            ));
        }
        for x in arr {
            let f = x
                .as_f64()
                .map(|f| f as f32)
                .filter(|f| f.is_finite())
                .ok_or_else(|| "features must be finite numbers".to_string())?;
            out.push(f);
        }
        Ok(())
    };
    if let Some(features) = v.get("features") {
        let arr = features.as_arr().ok_or("'features' must be an array")?;
        push_row(arr, out)?;
        return Ok(1);
    }
    if let Some(rows) = v.get("rows") {
        let rows = rows.as_arr().ok_or("'rows' must be an array of arrays")?;
        if rows.is_empty() {
            return Err("'rows' must not be empty".into());
        }
        if rows.len() > max_rows {
            return Err(format!("too many rows ({} > {max_rows})", rows.len()));
        }
        for row in rows {
            push_row(row.as_arr().ok_or("'rows' must be an array of arrays")?, out)?;
        }
        return Ok(rows.len());
    }
    Err("body must carry 'features' (one row) or 'rows' (a batch)".into())
}

/// Serialize the success response body straight into the connection's
/// reusable write buffer — no `Json` tree, no row clones (the response
/// serialization satellite). Field set and key order match the legacy
/// `obj(...)` (BTreeMap-alphabetical) rendering. With `debug` set
/// (`X-Acdc-Debug: 1`) a `"trace"` object carries the request's inline
/// stage breakdown from `arena.span`.
#[allow(clippy::too_many_arguments)]
fn write_infer_body(
    buf: &mut Vec<u8>,
    model: &str,
    version: u64,
    rows: usize,
    width: usize,
    queue_us: u64,
    execute_us: u64,
    arena: &InferArena,
    debug: bool,
) {
    buf.clear();
    buf.extend_from_slice(b"{\"batch_sizes\":[");
    for r in 0..rows {
        if r > 0 {
            buf.push(b',');
        }
        let _ = write!(buf, "{}", arena.batch_sizes[r]);
    }
    let _ = write!(buf, "],\"execute_us\":{execute_us},\"model\":\"{model}\"");
    if rows == 1 {
        buf.extend_from_slice(b",\"output\":");
        write_row_json(buf, &arena.outs[..arena.out_lens[0]]);
    }
    buf.extend_from_slice(b",\"outputs\":[");
    for r in 0..rows {
        if r > 0 {
            buf.push(b',');
        }
        let start = r * width;
        write_row_json(buf, &arena.outs[start..start + arena.out_lens[r]]);
    }
    let _ = write!(buf, "],\"queue_us\":{queue_us},\"rows\":{rows}");
    if debug {
        // Serialize/write haven't happened yet when the body is built —
        // those two stages are visible via the slow ring and /metrics.
        let span = &arena.span;
        let us = |s: Stage| span.get(s) / 1_000;
        let _ = write!(
            buf,
            ",\"trace\":{{\"admission_us\":{},\"batch_form_us\":{},\"execute_us\":{},\
             \"id\":\"{:016x}\",\"parse_us\":{},\"queue_wait_us\":{}}}",
            us(Stage::Admission),
            us(Stage::BatchForm),
            us(Stage::Execute),
            span.trace_id,
            us(Stage::Parse),
            us(Stage::QueueWait),
        );
    }
    let _ = write!(buf, ",\"version\":{version}}}");
}

/// One output row as a JSON array of numbers.
fn write_row_json(buf: &mut Vec<u8>, vals: &[f32]) {
    buf.push(b'[');
    for (i, &v) in vals.iter().enumerate() {
        if i > 0 {
            buf.push(b',');
        }
        write_json_f32(buf, v);
    }
    buf.push(b']');
}

/// One float in the same rendering `Json::Num` uses: integral magnitudes
/// below 1e15 print as integers, non-finite values as `null`.
fn write_json_f32(buf: &mut Vec<u8>, v: f32) {
    let n = v as f64;
    if !n.is_finite() {
        buf.extend_from_slice(b"null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(buf, "{}", n as i64);
    } else {
        let _ = write!(buf, "{n}");
    }
}

/// The request's deadline budget in milliseconds: the
/// `x-acdc-deadline-ms` header clamped to `[1, limits.max_deadline_ms]`,
/// or `limits.default_deadline_ms` when the header is absent. A
/// malformed header is a 400 — running an unbounded request against a
/// garbled budget would defeat the point of asking for one. Header
/// parsing is wire-format agnostic, so JSON and binary-frame requests
/// share this path bit-for-bit.
fn deadline_budget_ms(shared: &Arc<Shared>, req: &RequestScratch) -> Result<u64, Response> {
    let requested = match req.header("x-acdc-deadline-ms") {
        None => None,
        Some(v) => Some(v.trim().parse::<u64>().map_err(|_| {
            Response::json(
                400,
                &err_json("x-acdc-deadline-ms must be a non-negative integer"),
            )
        })?),
    };
    Ok(shared.cfg.limits.clamp_deadline_ms(requested))
}

fn shed_response(shared: &Arc<Shared>, e: AdmitError) -> Response {
    shed_retry_after(shared, e.status(), e.as_str())
}

fn shed_retry_after(shared: &Arc<Shared>, status: u16, msg: &str) -> Response {
    Response::json(status, &err_json(msg))
        .with_header("retry-after", &shared.cfg.retry_after_s.to_string())
}

fn err_json(msg: &str) -> Json {
    obj(vec![("error", Json::Str(msg.to_string()))])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dom_rows(body: &str, width: usize, max_rows: usize) -> Result<(usize, Vec<f32>), String> {
        let v = Json::parse(body).map_err(|e| e.to_string())?;
        let mut out = Vec::new();
        let rows = extract_rows_dom(&v, width, max_rows, &mut out)?;
        Ok((rows, out))
    }

    #[test]
    fn extract_rows_dom_single_and_batch() {
        assert_eq!(
            dom_rows(r#"{"features": [1.0, 2.0]}"#, 2, 8).unwrap(),
            (1, vec![1.0, 2.0])
        );
        assert_eq!(
            dom_rows(r#"{"rows": [[1, 2], [3, 4], [5, 6]]}"#, 2, 8).unwrap(),
            (3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
        );
    }

    #[test]
    fn extract_rows_dom_validates_width_count_and_values() {
        assert!(dom_rows(r#"{"features": [1.0]}"#, 2, 8)
            .unwrap_err()
            .contains("width"));
        assert!(dom_rows(r#"{"rows": []}"#, 2, 8).is_err());
        assert!(dom_rows(r#"{"rows": [[1,2],[3,4],[5,6]]}"#, 2, 2)
            .unwrap_err()
            .contains("too many"));
        assert!(dom_rows(r#"{"features": [1.0, "x"]}"#, 2, 8).is_err());
        assert!(dom_rows(r#"{"nope": 1}"#, 2, 8).is_err());
    }

    #[test]
    fn fast_parser_accepts_canonical_bodies() {
        let mut out = Vec::new();
        assert_eq!(
            parse_infer_fast(r#"{"features": [1.0, -2.5]}"#, 2, 8, &mut out).unwrap(),
            Some(1)
        );
        assert_eq!(out, vec![1.0, -2.5]);
        assert_eq!(
            parse_infer_fast(r#"{ "rows" : [[1,2],[3.5,4e1]] }"#, 2, 8, &mut out).unwrap(),
            Some(2)
        );
        assert_eq!(out, vec![1.0, 2.0, 3.5, 40.0]);
        // Exactly what the load generator emits.
        assert_eq!(
            parse_infer_fast(r#"{"features":[0.5,0.25]}"#, 2, 8, &mut out).unwrap(),
            Some(1)
        );
        assert_eq!(out, vec![0.5, 0.25]);
    }

    #[test]
    fn fast_parser_falls_back_on_non_canonical_shapes() {
        let mut out = Vec::new();
        // Extra keys, strings, escapes, lax numbers → DOM fallback.
        for body in [
            r#"{"features": [1, 2], "extra": 1}"#,
            r#"{"rows": [[1, "x"]]}"#,
            r#"{"features": [+1, 2]}"#,
            r#"{"features": [1., 2]}"#,
            r#"{"features": [01, 2]}"#,
            r#"{"features": [1e999, 2]}"#,
            r#"["features"]"#,
            r#"{"features": [1, 2]} trailing"#,
        ] {
            assert_eq!(
                parse_infer_fast(body, 2, 8, &mut out).unwrap(),
                None,
                "{body}"
            );
        }
    }

    #[test]
    fn fast_parser_reports_definite_errors() {
        let mut out = Vec::new();
        assert!(parse_infer_fast(r#"{"features": [1.0]}"#, 2, 8, &mut out)
            .unwrap_err()
            .contains("width"));
        assert!(parse_infer_fast(r#"{"rows": []}"#, 2, 8, &mut out)
            .unwrap_err()
            .contains("empty"));
        assert!(parse_infer_fast(r#"{"rows": [[1,2],[3,4],[5,6]]}"#, 2, 2, &mut out)
            .unwrap_err()
            .contains("too many"));
    }

    #[test]
    fn fast_parser_agrees_with_dom_on_canonical_bodies() {
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        for rows in [1usize, 3] {
            let width = 4;
            let vals = rng.normal_vec(rows * width, 0.0, 1.0);
            let body = if rows == 1 {
                format!(
                    "{{\"features\":[{}]}}",
                    vals.iter()
                        .map(|v| format!("{v}"))
                        .collect::<Vec<_>>()
                        .join(",")
                )
            } else {
                let rows_json: Vec<String> = vals
                    .chunks(width)
                    .map(|row| {
                        format!(
                            "[{}]",
                            row.iter()
                                .map(|v| format!("{v}"))
                                .collect::<Vec<_>>()
                                .join(",")
                        )
                    })
                    .collect();
                format!("{{\"rows\":[{}]}}", rows_json.join(","))
            };
            let mut fast = Vec::new();
            let got = parse_infer_fast(&body, width, 8, &mut fast).unwrap();
            assert_eq!(got, Some(rows), "{body}");
            let (dom_n, dom) = dom_rows(&body, width, 8).unwrap();
            assert_eq!(dom_n, rows);
            assert_eq!(fast, dom, "fast and DOM parses must agree bitwise");
        }
    }

    #[test]
    fn infer_route_matches_inference_posts_only() {
        assert_eq!(infer_route("POST", "/v1/infer"), Some(None));
        assert_eq!(infer_route("POST", "/v1/models/m/infer"), Some(Some("m")));
        assert_eq!(infer_route("GET", "/v1/infer"), None);
        assert_eq!(infer_route("POST", "/v1/models//infer"), None);
        assert_eq!(infer_route("POST", "/v1/models/a/b/infer"), None);
        assert_eq!(infer_route("POST", "/v1/models"), None);
    }

    #[test]
    fn response_body_writer_matches_json_rendering() {
        let mut arena = InferArena::default();
        arena.ensure(2, 3);
        arena.rows.resize(6, 0.0);
        arena.outs[..6].copy_from_slice(&[1.0, 2.5, -3.0, 0.5, f32::NAN, 7.0]);
        arena.out_lens[0] = 3;
        arena.out_lens[1] = 3;
        arena.batch_sizes[0] = 4;
        arena.batch_sizes[1] = 4;
        let mut buf = Vec::new();
        write_infer_body(&mut buf, "demo", 3, 2, 3, 17, 42, &arena, false);
        let parsed = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        assert_eq!(parsed.get("model").unwrap().as_str(), Some("demo"));
        assert_eq!(parsed.get("version").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.get("rows").unwrap().as_f64(), Some(2.0));
        assert_eq!(parsed.get("queue_us").unwrap().as_f64(), Some(17.0));
        assert_eq!(parsed.get("execute_us").unwrap().as_f64(), Some(42.0));
        assert!(parsed.get("trace").is_none(), "trace object is opt-in");
        let outs = parsed.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].as_arr().unwrap()[1].as_f64(), Some(2.5));
        // NaN renders as null, exactly like Json::Num.
        assert_eq!(outs[1].as_arr().unwrap()[1], Json::Null);
        assert!(parsed.get("output").is_none(), "single-row field only at rows=1");
        // Single-row rendering carries both "output" and "outputs".
        write_infer_body(&mut buf, "demo", 1, 1, 3, 0, 0, &arena, false);
        let parsed = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        assert_eq!(
            parsed.get("output").unwrap().as_arr().unwrap().len(),
            3,
            "{parsed}"
        );
    }

    #[test]
    fn response_body_debug_trace_object_renders_stage_breakdown() {
        let mut arena = InferArena::default();
        arena.ensure(1, 2);
        arena.rows.resize(2, 0.0);
        arena.outs[..2].copy_from_slice(&[1.0, 2.0]);
        arena.out_lens[0] = 2;
        arena.batch_sizes[0] = 4;
        arena.span.trace_id = 0xab;
        arena.span.set(Stage::Parse, Duration::from_micros(3));
        arena.span.set(Stage::Admission, Duration::from_micros(1));
        arena.span.set(Stage::QueueWait, Duration::from_micros(250));
        arena.span.set(Stage::BatchForm, Duration::from_micros(9));
        arena.span.set(Stage::Execute, Duration::from_micros(700));
        let mut buf = Vec::new();
        write_infer_body(&mut buf, "demo", 1, 1, 2, 250, 700, &arena, true);
        let parsed = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
        let tr = parsed.get("trace").expect("trace object present");
        assert_eq!(tr.get("id").unwrap().as_str(), Some("00000000000000ab"));
        assert_eq!(tr.get("parse_us").unwrap().as_f64(), Some(3.0));
        assert_eq!(tr.get("queue_wait_us").unwrap().as_f64(), Some(250.0));
        assert_eq!(tr.get("batch_form_us").unwrap().as_f64(), Some(9.0));
        assert_eq!(tr.get("execute_us").unwrap().as_f64(), Some(700.0));
        assert_eq!(tr.get("admission_us").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn conn_tracker_caps_counts_and_drains() {
        let gauge = Arc::new(Gauge::default());
        let t = ConnTracker::new(Arc::clone(&gauge));
        assert!(t.try_enter(2));
        assert!(t.try_enter(2));
        assert!(!t.try_enter(2), "cap reached");
        assert_eq!((t.open(), gauge.get()), (2, 2), "gauge mirrors count");
        // Non-blocking drain check fails while connections are open…
        assert!(!t.wait_idle(Instant::now()));
        t.exit();
        t.exit();
        // …and succeeds immediately once they exit.
        assert!(t.wait_idle(Instant::now()));
        assert_eq!((t.open(), gauge.get()), (0, 0));
    }

    #[test]
    fn conn_tracker_wait_wakes_on_exit() {
        let t = Arc::new(ConnTracker::new(Arc::new(Gauge::default())));
        assert!(t.try_enter(8));
        let t2 = Arc::clone(&t);
        let waiter = std::thread::spawn(move || {
            t2.wait_idle(Instant::now() + Duration::from_secs(10))
        });
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        t.exit();
        assert!(waiter.join().unwrap(), "drain must observe the exit");
        // The waiter returned on the notify, far before the 10s deadline.
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
