//! The network gateway: a TCP/HTTP front-end over the batching coordinator.
//!
//! Thread-per-connection accept loop with keep-alive; every request passes
//! admission control ([`super::admission`]) before touching the
//! coordinator. Endpoints:
//!
//! * `POST /v1/infer` — JSON body `{"features": [f32; N]}` for one row or
//!   `{"rows": [[f32; N], ...]}` for a batch; replies with outputs plus
//!   queue/execute timings and the batch buckets used. Sheds map to
//!   429/503 with `Retry-After`, coordinator timeouts to 504.
//! * `GET /healthz` — liveness + drain state + in-flight gauge.
//! * `GET /metrics` — Prometheus text from [`crate::metrics::Registry`].
//!
//! Shutdown is a graceful drain: stop accepting, refuse new work at
//! admission, let in-flight requests finish and connections close, then
//! tear the coordinator down (which itself flushes its queues).

use std::io::{BufReader, ErrorKind};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::admission::{Admission, AdmitError};
use super::http::{self, HttpError, ReadOutcome, Request, Response};
use crate::config::GatewayConfig;
use crate::coordinator::SubmitError;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::serve::Server;
use crate::util::json::{obj, Json};

/// Poll interval for parked keep-alive connections (also bounds how fast
/// idle connections notice a drain).
const IDLE_POLL: Duration = Duration::from_millis(50);

/// Running gateway handle. Dropping it (or calling [`Gateway::shutdown`])
/// drains gracefully.
pub struct Gateway {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

struct Shared {
    server: Server,
    cfg: GatewayConfig,
    admission: Arc<Admission>,
    metrics: Arc<Registry>,
    stop: AtomicBool,
    open_conns: Arc<Gauge>,
    conns_total: Arc<Counter>,
    conns_rejected: Arc<Counter>,
    requests: Arc<Counter>,
    responses_ok: Arc<Counter>,
    http_errors: Arc<Counter>,
    timeouts: Arc<Counter>,
    request_ns: Arc<Histogram>,
}

impl Gateway {
    /// Bind `cfg.addr` (port 0 for ephemeral) and start serving `server`.
    pub fn start(server: Server, cfg: GatewayConfig) -> Result<Gateway, String> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("gateway bind {}: {e}", cfg.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("gateway local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("gateway set_nonblocking: {e}"))?;
        let metrics = Arc::clone(server.metrics());
        let admission = Arc::new(Admission::new(&cfg, &metrics));
        let shared = Arc::new(Shared {
            server,
            cfg,
            admission,
            open_conns: metrics.gauge("gateway.open_connections"),
            conns_total: metrics.counter("gateway.connections"),
            conns_rejected: metrics.counter("gateway.connections_rejected"),
            requests: metrics.counter("gateway.requests"),
            responses_ok: metrics.counter("gateway.responses_ok"),
            http_errors: metrics.counter("gateway.http_errors"),
            timeouts: metrics.counter("gateway.timeouts"),
            request_ns: metrics.histogram("gateway.request_ns"),
            metrics,
            stop: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("acdc-gw-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .map_err(|e| format!("spawn accept loop: {e}"))?;
        Ok(Gateway {
            shared,
            addr,
            accept: Some(accept),
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared metrics registry (gateway + coordinator + workers).
    pub fn metrics(&self) -> &Arc<Registry> {
        &self.shared.metrics
    }

    /// Text metrics report (the non-Prometheus rendering).
    pub fn metrics_report(&self) -> String {
        self.shared.metrics.report()
    }

    /// Graceful drain, then coordinator teardown. Equivalent to `drop`.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.shared.admission.begin_drain();
        self.shared.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Connection threads finish their in-flight request, write the
        // response and exit (they observe the drain within IDLE_POLL).
        let deadline = Instant::now() + Duration::from_millis(self.shared.cfg.drain_timeout_ms);
        while self.shared.open_conns.get() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        // The coordinator itself drains in `Coordinator::drop` once the
        // last `Shared` clone (ours, or a straggler past the deadline)
        // goes away — in-flight work is answered either way.
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.conns_total.inc();
                if shared.open_conns.inc() > shared.cfg.max_open_conns as u64 {
                    shared.open_conns.dec();
                    shared.conns_rejected.inc();
                    reject_connection(stream, shared.cfg.retry_after_s);
                    continue;
                }
                let conn_shared = Arc::clone(&shared);
                let spawned = std::thread::Builder::new()
                    .name("acdc-gw-conn".into())
                    .spawn(move || handle_connection(conn_shared, stream));
                if spawned.is_err() {
                    shared.open_conns.dec();
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Over the connection cap: answer 503 on the raw socket and close.
fn reject_connection(mut stream: TcpStream, retry_after_s: u64) {
    let _ = stream.set_nonblocking(false);
    let resp = Response::json(503, &err_json("too many connections"))
        .with_header("retry-after", &retry_after_s.to_string());
    let _ = resp.write_to(&mut stream, false);
}

/// Releases the accept loop's `open_conns` slot even if the connection
/// thread unwinds (a leaked slot would eventually wedge admission and
/// drain behind `max_open_conns`).
struct ConnSlot(Arc<Gauge>);

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.dec();
    }
}

fn handle_connection(shared: Arc<Shared>, stream: TcpStream) {
    let _slot = ConnSlot(Arc::clone(&shared.open_conns));
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    loop {
        match http::read_request(&mut reader, shared.cfg.max_body_bytes) {
            Ok(ReadOutcome::Idle) => {
                if shared.stop.load(Ordering::Acquire) || shared.admission.is_draining() {
                    break;
                }
            }
            Ok(ReadOutcome::Eof) => break,
            Ok(ReadOutcome::Request(req)) => {
                let t0 = Instant::now();
                shared.requests.inc();
                let resp = route(&shared, &req);
                shared.request_ns.record(t0.elapsed());
                if resp.status == 200 {
                    shared.responses_ok.inc();
                }
                let keep = req.wants_keep_alive()
                    && !shared.stop.load(Ordering::Acquire)
                    && !shared.admission.is_draining();
                if resp.write_to(&mut writer, keep).is_err() || !keep {
                    break;
                }
            }
            Err(HttpError::BodyTooLarge(n)) => {
                shared.http_errors.inc();
                let msg = format!("body too large ({n} > {} bytes)", shared.cfg.max_body_bytes);
                let _ = Response::json(413, &err_json(&msg)).write_to(&mut writer, false);
                break;
            }
            Err(HttpError::Malformed(m)) => {
                shared.http_errors.inc();
                let _ = Response::json(400, &err_json(&m)).write_to(&mut writer, false);
                break;
            }
            Err(HttpError::Io(_)) => break,
        }
    }
}

fn route(shared: &Arc<Shared>, req: &Request) -> Response {
    match (req.method.as_str(), req.route_path()) {
        ("GET", "/healthz") => healthz(shared),
        ("GET", "/metrics") => Response::text(200, &shared.metrics.prometheus()),
        ("POST", "/v1/infer") => infer(shared, req),
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/infer") => {
            Response::json(405, &err_json("method not allowed"))
        }
        _ => Response::json(404, &err_json("not found")),
    }
}

fn healthz(shared: &Arc<Shared>) -> Response {
    let status = if shared.admission.is_draining() {
        "draining"
    } else {
        "ok"
    };
    Response::json(
        200,
        &obj(vec![
            ("status", Json::Str(status.to_string())),
            ("width", Json::Num(shared.server.width() as f64)),
            ("inflight", Json::Num(shared.admission.inflight() as f64)),
            (
                "open_connections",
                Json::Num(shared.open_conns.get() as f64),
            ),
        ]),
    )
}

fn infer(shared: &Arc<Shared>, req: &Request) -> Response {
    // The permit holds an in-flight slot for the whole submit → response
    // window; dropping it on any exit path releases the slot.
    let _permit = match shared.admission.try_admit() {
        Ok(p) => p,
        Err(e) => return shed_response(shared, e),
    };
    let body = match std::str::from_utf8(&req.body) {
        Ok(s) => s,
        Err(_) => return Response::json(400, &err_json("body is not valid utf-8")),
    };
    let parsed = match Json::parse(body) {
        Ok(v) => v,
        Err(e) => return Response::json(400, &err_json(&format!("bad json: {e}"))),
    };
    let rows = match extract_rows(&parsed, shared.server.width(), shared.cfg.max_rows_per_request)
    {
        Ok(rows) => rows,
        Err(msg) => return Response::json(400, &err_json(&msg)),
    };
    let mut rxs = Vec::with_capacity(rows.len());
    for row in rows {
        match shared.server.submit(row) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::QueueFull) => {
                shared.admission.note_queue_full();
                return shed_retry_after(shared, 503, "coordinator queue full");
            }
            Err(SubmitError::Closed) => {
                return shed_retry_after(shared, 503, "coordinator shutting down");
            }
        }
    }
    // Rows submitted before a mid-batch shed are still answered by the
    // coordinator; their receivers simply drop here.
    let deadline = Instant::now() + Duration::from_millis(shared.cfg.request_timeout_ms);
    let mut outputs = Vec::with_capacity(rxs.len());
    let mut batch_sizes = Vec::with_capacity(rxs.len());
    let mut queue_us = 0u64;
    let mut execute_us = 0u64;
    for rx in rxs {
        let remaining = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(remaining) {
            Ok(resp) => {
                queue_us = queue_us.max(resp.queue_us);
                execute_us = execute_us.max(resp.execute_us);
                batch_sizes.push(Json::Num(resp.batch_size as f64));
                match resp.output {
                    Ok(row) => outputs.push(Json::Arr(
                        row.into_iter().map(|v| Json::Num(v as f64)).collect(),
                    )),
                    Err(e) => {
                        return Response::json(500, &err_json(&format!("executor: {e}")))
                    }
                }
            }
            Err(_) => {
                shared.timeouts.inc();
                return Response::json(504, &err_json("inference timed out"));
            }
        }
    }
    let mut pairs = vec![
        ("rows", Json::Num(outputs.len() as f64)),
        ("queue_us", Json::Num(queue_us as f64)),
        ("execute_us", Json::Num(execute_us as f64)),
        ("batch_sizes", Json::Arr(batch_sizes)),
    ];
    if outputs.len() == 1 {
        pairs.push(("output", outputs[0].clone()));
    }
    pairs.push(("outputs", Json::Arr(outputs)));
    Response::json(200, &obj(pairs))
}

/// Feature rows from a request body: `{"features": [...]}` (one row) or
/// `{"rows": [[...], ...]}` (a batch).
fn extract_rows(v: &Json, width: usize, max_rows: usize) -> Result<Vec<Vec<f32>>, String> {
    let parse_row = |arr: &[Json]| -> Result<Vec<f32>, String> {
        if arr.len() != width {
            return Err(format!(
                "row has {} features, model width is {width}",
                arr.len()
            ));
        }
        arr.iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as f32)
                    .filter(|f| f.is_finite())
                    .ok_or_else(|| "features must be finite numbers".to_string())
            })
            .collect()
    };
    if let Some(features) = v.get("features") {
        let arr = features.as_arr().ok_or("'features' must be an array")?;
        return Ok(vec![parse_row(arr)?]);
    }
    if let Some(rows) = v.get("rows") {
        let rows = rows.as_arr().ok_or("'rows' must be an array of arrays")?;
        if rows.is_empty() {
            return Err("'rows' must not be empty".into());
        }
        if rows.len() > max_rows {
            return Err(format!("too many rows ({} > {max_rows})", rows.len()));
        }
        return rows
            .iter()
            .map(|row| parse_row(row.as_arr().ok_or("'rows' must be an array of arrays")?))
            .collect();
    }
    Err("body must carry 'features' (one row) or 'rows' (a batch)".into())
}

fn shed_response(shared: &Arc<Shared>, e: AdmitError) -> Response {
    shed_retry_after(shared, e.status(), e.as_str())
}

fn shed_retry_after(shared: &Arc<Shared>, status: u16, msg: &str) -> Response {
    Response::json(status, &err_json(msg))
        .with_header("retry-after", &shared.cfg.retry_after_s.to_string())
}

fn err_json(msg: &str) -> Json {
    obj(vec![("error", Json::Str(msg.to_string()))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extract_rows_single_and_batch() {
        let v = Json::parse(r#"{"features": [1.0, 2.0]}"#).unwrap();
        assert_eq!(extract_rows(&v, 2, 8).unwrap(), vec![vec![1.0, 2.0]]);
        let v = Json::parse(r#"{"rows": [[1, 2], [3, 4], [5, 6]]}"#).unwrap();
        assert_eq!(
            extract_rows(&v, 2, 8).unwrap(),
            vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]
        );
    }

    #[test]
    fn extract_rows_validates_width_count_and_values() {
        let v = Json::parse(r#"{"features": [1.0]}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).unwrap_err().contains("width"));
        let v = Json::parse(r#"{"rows": []}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).is_err());
        let v = Json::parse(r#"{"rows": [[1,2],[3,4],[5,6]]}"#).unwrap();
        assert!(extract_rows(&v, 2, 2).unwrap_err().contains("too many"));
        let v = Json::parse(r#"{"features": [1.0, "x"]}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).is_err());
        let v = Json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(extract_rows(&v, 2, 8).is_err());
    }
}
