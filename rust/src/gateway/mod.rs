//! Network serving gateway: TCP/HTTP front-end, admission control, and a
//! closed-loop load generator over the batching coordinator.
//!
//! This is the layer that puts the ACDC serving stack "on the wire" — the
//! paper's O(N log N) layer only pays off at scale if the substrate around
//! it can absorb and shape real concurrent traffic:
//!
//! ```text
//!   clients ──TCP──▶ acceptor ──▶ epoll shards (10k+ keep-alive conns)
//!                                        │ complete frame
//!                                  dispatch pool (bounded workers;
//!                                  threaded mode: one thread per conn)
//!                                        │
//!                                 admission control
//!                            (drain → in-flight cap → token bucket)
//!                                        │ resolve (model, version)
//!                                  ModelRegistry ([`crate::registry`]:
//!                                  named models, Arc-epoch hot swap)
//!                                        │ submit
//!                                  per-model Coordinator (bounded queue,
//!                                  bucketed batcher, worker pool)
//!                                        │
//!                                  SELL executors (PJRT or native)
//! ```
//!
//! * [`http`] — dependency-free HTTP/1.1 framing (server + client side);
//! * [`wire`] — the length-prefixed binary f32 inference frame
//!   (`Content-Type: application/x-acdc-f32`), bit-identical to JSON;
//! * [`admission`] — token bucket, in-flight cap, drain gate, shed
//!   accounting;
//! * [`brownout`] — the degradation ladder a pressured gateway walks
//!   (disable hedging → coarsen tracing → shed batches → shed all but
//!   health traffic) with hysteresis in both directions;
//! * [`server`] — [`Gateway`]: routing, the shared request pipeline,
//!   graceful drain, and the thread-per-connection fallback;
//! * `reactor` — the dependency-free epoll event loop behind the default
//!   `gateway.mode = "reactor"`;
//! * [`loadgen`] — closed/open-loop traffic with raw and
//!   coordinated-omission-corrected p50/p95/p99 reports, single- or
//!   multi-target (`--targets` across shards or routers).
//!
//! Every shed path is observable: `429`/`503` responses carry
//! `Retry-After`, and `GET /metrics` exposes per-class shed counters next
//! to the coordinator's own instruments.
//!
//! In **cluster mode** ([`crate::cluster`]) this same gateway serves two
//! roles: a *shard* is exactly the pipeline above, while a *router*
//! (started via [`Gateway::start_router`]) intercepts inference routes
//! before the local pipeline and proxies them across the shard topology
//! with replication, health-checked retry, and hedging — both I/O modes
//! included, since they share `server::serve_request`.

pub mod admission;
pub mod brownout;
pub mod http;
pub mod loadgen;
mod reactor;
pub mod server;
pub mod wire;

pub use server::Gateway;
