//! Network serving gateway: TCP/HTTP front-end, admission control, and a
//! closed-loop load generator over the batching coordinator.
//!
//! This is the layer that puts the ACDC serving stack "on the wire" — the
//! paper's O(N log N) layer only pays off at scale if the substrate around
//! it can absorb and shape real concurrent traffic:
//!
//! ```text
//!   clients ──TCP──▶ accept loop ──▶ conn threads (HTTP/1.1 keep-alive)
//!                                        │
//!                                 admission control
//!                            (drain → in-flight cap → token bucket)
//!                                        │ resolve (model, version)
//!                                  ModelRegistry ([`crate::registry`]:
//!                                  named models, Arc-epoch hot swap)
//!                                        │ submit
//!                                  per-model Coordinator (bounded queue,
//!                                  bucketed batcher, worker pool)
//!                                        │
//!                                  SELL executors (PJRT or native)
//! ```
//!
//! * [`http`] — dependency-free HTTP/1.1 framing (server + client side);
//! * [`admission`] — token bucket, in-flight cap, drain gate, shed
//!   accounting;
//! * [`server`] — [`Gateway`]: listener, routing, graceful drain;
//! * [`loadgen`] — closed/open-loop traffic with a p50/p95/p99 report.
//!
//! Every shed path is observable: `429`/`503` responses carry
//! `Retry-After`, and `GET /metrics` exposes per-class shed counters next
//! to the coordinator's own instruments.

pub mod admission;
pub mod http;
pub mod loadgen;
pub mod server;

pub use server::Gateway;
