//! Dependency-free HTTP/1.1 framing for the serving gateway.
//!
//! Server side: [`read_request`] parses one request off a `BufRead`
//! (request line, headers, `Content-Length` body) and distinguishes a
//! *parked* keep-alive connection ([`ReadOutcome::Idle`], a read timeout
//! before any bytes) from a *stalled* peer mid-request (an error after a
//! bounded retry window). [`Response`] renders status/headers/body with
//! explicit `Content-Length` and `Connection` headers.
//!
//! Client side ([`write_request`], [`read_response`]) is used by the
//! load generator and the integration tests; both ends speak the same
//! deliberately small dialect: no chunked transfer, no trailers, bodies
//! always length-delimited.

use std::fmt;
use std::io::{BufRead, ErrorKind, Read, Write};
use std::time::{Duration, Instant};

/// Reject header sections larger than this.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// Cap on any single line (request line, header, status line) — bounds
/// memory against a peer streaming bytes with no newline.
const MAX_LINE_BYTES: usize = MAX_HEADER_BYTES;

/// How long a peer may stall mid-message before the connection is dropped.
const STALL_DEADLINE: Duration = Duration::from_secs(10);

/// Framing error. `BodyTooLarge` and `Malformed` are answerable with a
/// status code; `Io` means the connection is unusable.
#[derive(Debug)]
pub enum HttpError {
    /// Declared body exceeds the configured cap (answer 413).
    BodyTooLarge(usize),
    /// Unparseable or unsupported message (answer 400).
    Malformed(String),
    /// Transport failure; drop the connection.
    Io(String),
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpError::BodyTooLarge(n) => write!(f, "request body too large ({n} bytes)"),
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
            HttpError::Io(m) => write!(f, "connection error: {m}"),
        }
    }
}

/// A parsed inbound request. Header names are lowercased at parse time.
#[derive(Debug)]
pub struct Request {
    /// HTTP method (e.g. `GET`, `POST`).
    pub method: String,
    /// Request target as sent (may include a query string).
    pub path: String,
    /// Protocol version (`HTTP/1.0` or `HTTP/1.1`).
    pub version: String,
    /// Headers in arrival order, names lowercased.
    pub headers: Vec<(String, String)>,
    /// Length-delimited body (empty without `Content-Length`).
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        find_header(&self.headers, name)
    }

    /// Path with any query string stripped (routing key).
    pub fn route_path(&self) -> &str {
        self.path.split('?').next().unwrap_or("")
    }

    /// HTTP/1.1 defaults to keep-alive unless `Connection` carries a
    /// `close` token; HTTP/1.0 requires an explicit `keep-alive` token.
    pub fn wants_keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("");
        if self.version == "HTTP/1.0" {
            connection_has_token(conn, "keep-alive")
        } else {
            !connection_has_token(conn, "close")
        }
    }
}

/// Whether a `Connection` header value carries `token` — the value is a
/// comma-separated token list (RFC 9110 §7.6.1), so `close, x-foo` must
/// count as close. Comparing the whole value against a single token (the
/// old behaviour) silently turned legal token lists into keep-alives and
/// left the peer waiting for an EOF that never came.
fn connection_has_token(value: &str, token: &str) -> bool {
    value
        .split(',')
        .any(|t| t.trim().eq_ignore_ascii_case(token))
}

/// What one read attempt produced.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete request.
    Request(Request),
    /// Clean close at a message boundary.
    Eof,
    /// Read timeout with no bytes received — connection is parked; the
    /// caller should poll its shutdown flag and retry.
    Idle,
}

enum LineRead {
    Line,
    Eof,
    Idle,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Read one `\n`-terminated line, retrying short read-timeouts until
/// `deadline`. `allow_idle` governs the empty-buffer timeout case. The
/// read is length-capped at [`MAX_LINE_BYTES`] so a peer streaming bytes
/// with no newline cannot grow memory without bound.
fn read_line_retry<R: BufRead>(
    r: &mut R,
    buf: &mut String,
    allow_idle: bool,
    deadline: Instant,
) -> Result<LineRead, HttpError> {
    loop {
        // +2 leaves room for the "\r\n" of a maximal line; hitting the
        // cap makes the limited reader report EOF mid-line below.
        let cap = (MAX_LINE_BYTES + 2).saturating_sub(buf.len()) as u64;
        let mut limited = r.by_ref().take(cap);
        match limited.read_line(buf) {
            Ok(0) => {
                return if buf.is_empty() {
                    Ok(LineRead::Eof)
                } else if buf.len() > MAX_LINE_BYTES {
                    Err(HttpError::Malformed("line too long".into()))
                } else {
                    Err(HttpError::Io("eof mid-line".into()))
                };
            }
            Ok(_) => {
                if buf.ends_with('\n') {
                    return Ok(LineRead::Line);
                }
                return if buf.len() > MAX_LINE_BYTES {
                    Err(HttpError::Malformed("line too long".into()))
                } else {
                    // read_line only stops short of '\n' at EOF.
                    Err(HttpError::Io("eof mid-line".into()))
                };
            }
            Err(e) if is_timeout(&e) => {
                if buf.is_empty() && allow_idle {
                    return Ok(LineRead::Idle);
                }
                if Instant::now() >= deadline {
                    return Err(HttpError::Io("peer stalled mid-message".into()));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::Io(e.to_string())),
        }
    }
}

/// Read `name: value` headers until the blank line; names lowercased.
fn read_headers<R: BufRead>(
    r: &mut R,
    deadline: Instant,
) -> Result<Vec<(String, String)>, HttpError> {
    let mut headers = Vec::new();
    let mut total = 0usize;
    loop {
        let mut line = String::new();
        match read_line_retry(r, &mut line, false, deadline)? {
            LineRead::Line => {}
            _ => return Err(HttpError::Io("eof in headers".into())),
        }
        let trimmed = line.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            return Ok(headers);
        }
        total += line.len();
        if total > MAX_HEADER_BYTES {
            return Err(HttpError::Malformed("header section too large".into()));
        }
        let (name, value) = trimmed
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("bad header line '{trimmed}'")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
}

/// Read an exact-length body, retrying short read-timeouts until `deadline`.
fn read_body<R: BufRead>(
    r: &mut R,
    len: usize,
    deadline: Instant,
) -> Result<Vec<u8>, HttpError> {
    let mut body = vec![0u8; len];
    read_exact_retry(r, &mut body, deadline)?;
    Ok(body)
}

/// Fill `buf` exactly, retrying short read-timeouts until `deadline`.
fn read_exact_retry<R: BufRead>(
    r: &mut R,
    buf: &mut [u8],
    deadline: Instant,
) -> Result<(), HttpError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(HttpError::Io("eof mid-body".into())),
            Ok(n) => filled += n,
            Err(e) if is_timeout(&e) => {
                if Instant::now() >= deadline {
                    return Err(HttpError::Io("peer stalled mid-body".into()));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::Io(e.to_string())),
        }
    }
    Ok(())
}

fn find_header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

/// The message's `Content-Length`, rejecting duplicates outright. Two
/// `Content-Length` headers (even with equal values) are the classic
/// request-smuggling/desync vector — a front-end and back-end that pick
/// different ones disagree on where this message ends — so both the
/// server and client parsers refuse the message instead of guessing.
fn content_length(headers: &[(String, String)]) -> Result<usize, HttpError> {
    let mut found: Option<&str> = None;
    for (k, v) in headers {
        if k.eq_ignore_ascii_case("content-length") {
            if found.is_some() {
                return Err(HttpError::Malformed(
                    "duplicate content-length header".into(),
                ));
            }
            found = Some(v.as_str());
        }
    }
    match found {
        None => Ok(0),
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::Malformed(format!("bad content-length '{v}'"))),
    }
}

/// Result of a non-destructive scan for one complete request frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameScan {
    /// The header section is still incomplete; more bytes are needed.
    Partial,
    /// The header section is complete and well-framed, but the body is
    /// not fully buffered yet: the frame is complete at exactly this
    /// many total bytes. Callers can cache the figure and compare
    /// against it on later reads instead of rescanning the header.
    NeedBody(usize),
    /// A parse attempt is guaranteed to terminate: either a complete
    /// head + body is buffered, or the buffered prefix already commits
    /// the parser to a deterministic error (oversize line, duplicate or
    /// malformed framing headers, over-cap body).
    Ready,
}

/// Decide whether `buf` holds enough of one request for
/// [`read_request_reusing`] to parse without blocking on more input —
/// the reactor shards call this on every read so a connection is only
/// handed to a dispatch worker once the parse cannot stall. The scanner
/// is deliberately *not* a validator: on any framing anomaly it reports
/// [`FrameScan::Ready`] and lets the authoritative parser produce the
/// error and status, so framing verdicts stay single-sourced.
pub fn scan_request_frame(buf: &[u8], max_body: usize) -> FrameScan {
    // A blank first line can never become a request; the parser answers
    // 400 from exactly these bytes.
    if buf.starts_with(b"\n") || buf.starts_with(b"\r\n") {
        return FrameScan::Ready;
    }
    let mut line_start = 0usize;
    let mut first_line = true;
    let mut header_total = 0usize;
    let mut head_end = None;
    let mut i = 0usize;
    while i < buf.len() {
        if buf[i] == b'\n' {
            let line_len = i + 1 - line_start;
            if line_len > MAX_LINE_BYTES + 2 {
                return FrameScan::Ready; // parser: "line too long"
            }
            let line = &buf[line_start..i];
            let content = match line.last() {
                Some(b'\r') => &line[..line.len() - 1],
                _ => line,
            };
            if !first_line {
                if content.is_empty() {
                    head_end = Some(i + 1);
                    break;
                }
                header_total += line_len;
                if header_total > MAX_HEADER_BYTES {
                    return FrameScan::Ready; // parser: "header section too large"
                }
            }
            first_line = false;
            line_start = i + 1;
        }
        i += 1;
    }
    let Some(head_end) = head_end else {
        // No header terminator yet. An over-cap trailing partial line
        // already commits the parser to "line too long".
        if buf.len() - line_start > MAX_LINE_BYTES + 2 {
            return FrameScan::Ready;
        }
        return FrameScan::Partial;
    };
    // Body framing: find the (single) content-length. Any anomaly —
    // duplicate, unparsable, non-UTF-8 name, colonless line, chunked
    // transfer — is Ready: the parser owns the verdict.
    let mut content_len = 0usize;
    let mut seen_cl = false;
    for line in buf[..head_end].split(|&c| c == b'\n').skip(1) {
        let line = match line.last() {
            Some(b'\r') => &line[..line.len() - 1],
            _ => line,
        };
        if line.is_empty() {
            continue;
        }
        let Some(colon) = line.iter().position(|&c| c == b':') else {
            return FrameScan::Ready; // parser: "bad header line"
        };
        let Ok(name) = std::str::from_utf8(&line[..colon]) else {
            return FrameScan::Ready; // parser: invalid UTF-8
        };
        let name = name.trim();
        if name.eq_ignore_ascii_case("transfer-encoding") {
            return FrameScan::Ready; // parser: unsupported
        }
        if name.eq_ignore_ascii_case("content-length") {
            if seen_cl {
                return FrameScan::Ready; // parser: duplicate content-length
            }
            seen_cl = true;
            let value = std::str::from_utf8(&line[colon + 1..]).unwrap_or("x");
            match value.trim().parse::<usize>() {
                Ok(n) => content_len = n,
                Err(_) => return FrameScan::Ready, // parser: bad content-length
            }
        }
    }
    if content_len > max_body {
        return FrameScan::Ready; // parser: 413
    }
    let total = head_end + content_len;
    if buf.len() >= total {
        FrameScan::Ready
    } else {
        FrameScan::NeedBody(total)
    }
}

/// Parse one request. See [`ReadOutcome`] for the idle/EOF contract.
///
/// Thin wrapper over [`read_request_reusing`] (one shared parse pipeline
/// — this allocating form is for clients/tests; the gateway's keep-alive
/// loop uses the scratch form directly).
pub fn read_request<R: BufRead>(r: &mut R, max_body: usize) -> Result<ReadOutcome, HttpError> {
    let mut s = RequestScratch::new();
    match read_request_reusing(r, max_body, &mut s)? {
        ScratchOutcome::Eof => Ok(ReadOutcome::Eof),
        ScratchOutcome::Idle => Ok(ReadOutcome::Idle),
        ScratchOutcome::Request => {
            s.headers.truncate(s.hdr_live);
            Ok(ReadOutcome::Request(Request {
                method: s.method,
                path: s.path,
                version: s.version,
                headers: s.headers,
                body: s.body,
            }))
        }
    }
}

/// Reusable per-connection request parse state: every buffer (line,
/// method/path/version, header names/values, body) is retained across
/// requests on a keep-alive connection, so steady-state request parsing
/// performs **zero heap allocations** once the buffers have grown to the
/// connection's request shape.
///
/// The accessors mirror [`Request`]; [`read_request_reusing`] fills it.
#[derive(Debug, Default)]
pub struct RequestScratch {
    line: String,
    /// HTTP method (e.g. `GET`, `POST`).
    pub method: String,
    /// Request target as sent (may include a query string).
    pub path: String,
    /// Protocol version (`HTTP/1.0` or `HTTP/1.1`).
    pub version: String,
    /// Header slots; only the first `hdr_live` are current.
    headers: Vec<(String, String)>,
    hdr_live: usize,
    /// Length-delimited body (empty without `Content-Length`).
    pub body: Vec<u8>,
}

impl RequestScratch {
    /// Empty scratch (buffers grow on first use).
    pub fn new() -> RequestScratch {
        RequestScratch::default()
    }

    /// Case-insensitive header lookup (current request only).
    pub fn header(&self, name: &str) -> Option<&str> {
        find_header(self.headers(), name)
    }

    /// The current request's headers, names lowercased.
    pub fn headers(&self) -> &[(String, String)] {
        &self.headers[..self.hdr_live]
    }

    /// Path with any query string stripped (routing key).
    pub fn route_path(&self) -> &str {
        self.path.split('?').next().unwrap_or("")
    }

    /// HTTP/1.1 defaults to keep-alive unless `Connection` carries a
    /// `close` token; HTTP/1.0 requires an explicit `keep-alive` token.
    pub fn wants_keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("");
        if self.version == "HTTP/1.0" {
            connection_has_token(conn, "keep-alive")
        } else {
            !connection_has_token(conn, "close")
        }
    }
}

/// Store one header into the scratch's slot pool, reusing the slot's
/// strings when one exists (free function so the caller can hold a borrow
/// of the scratch's line buffer at the same time).
fn push_header_reusing(
    headers: &mut Vec<(String, String)>,
    live: &mut usize,
    name: &str,
    value: &str,
) {
    if *live < headers.len() {
        let (k, v) = &mut headers[*live];
        k.clear();
        for c in name.chars() {
            k.push(c.to_ascii_lowercase());
        }
        v.clear();
        v.push_str(value);
    } else {
        headers.push((name.to_ascii_lowercase(), value.to_string()));
    }
    *live += 1;
}

/// What one [`read_request_reusing`] attempt produced (on `Request` the
/// scratch holds the parsed request).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScratchOutcome {
    /// A complete request is in the scratch.
    Request,
    /// Clean close at a message boundary.
    Eof,
    /// Read timeout with no bytes — connection parked; poll and retry.
    Idle,
}

/// [`read_request`] into reusable buffers — the gateway's keep-alive hot
/// path (no allocation once the scratch has warmed up). Same framing
/// contract and error behaviour as [`read_request`].
pub fn read_request_reusing<R: BufRead>(
    r: &mut R,
    max_body: usize,
    s: &mut RequestScratch,
) -> Result<ScratchOutcome, HttpError> {
    let deadline = Instant::now() + STALL_DEADLINE;
    s.line.clear();
    match read_line_retry(r, &mut s.line, true, deadline)? {
        LineRead::Line => {}
        LineRead::Eof => return Ok(ScratchOutcome::Eof),
        LineRead::Idle => return Ok(ScratchOutcome::Idle),
    }
    s.method.clear();
    s.path.clear();
    s.version.clear();
    {
        let trimmed = s.line.trim_end_matches(['\r', '\n']);
        let mut parts = trimmed.splitn(3, ' ');
        let m = parts.next().unwrap_or("");
        let p = parts.next().unwrap_or("");
        let v = parts.next().unwrap_or("");
        if m.is_empty() || p.is_empty() || !v.starts_with("HTTP/1.") {
            return Err(HttpError::Malformed(format!("bad request line '{trimmed}'")));
        }
        s.method.push_str(m);
        s.path.push_str(p);
        s.version.push_str(v);
    }
    s.hdr_live = 0;
    let mut total = 0usize;
    loop {
        s.line.clear();
        match read_line_retry(r, &mut s.line, false, deadline)? {
            LineRead::Line => {}
            _ => return Err(HttpError::Io("eof in headers".into())),
        }
        let trimmed = s.line.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        total += s.line.len();
        if total > MAX_HEADER_BYTES {
            return Err(HttpError::Malformed("header section too large".into()));
        }
        let (name, value) = trimmed
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("bad header line '{trimmed}'")))?;
        push_header_reusing(&mut s.headers, &mut s.hdr_live, name.trim(), value.trim());
    }
    if find_header(s.headers(), "transfer-encoding").is_some() {
        return Err(HttpError::Malformed("transfer-encoding not supported".into()));
    }
    let len = content_length(s.headers())?;
    if len > max_body {
        return Err(HttpError::BodyTooLarge(len));
    }
    s.body.clear();
    s.body.resize(len, 0);
    read_exact_retry(r, &mut s.body, deadline)?;
    Ok(ScratchOutcome::Request)
}

/// Serialize a response head into `head` (cleared first): status line,
/// content-type, explicit `content-length` for a body of `body_len`
/// bytes, and the `connection` header. Writing into a retained buffer
/// keeps the streamed response path allocation-free.
pub fn write_head(
    head: &mut Vec<u8>,
    status: u16,
    content_type: &str,
    body_len: usize,
    keep_alive: bool,
) {
    use std::io::Write as _;
    head.clear();
    let _ = write!(
        head,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        body_len,
        if keep_alive { "keep-alive" } else { "close" },
    );
}

/// [`write_head`] plus an `x-trace-id` response header: the trace ID is
/// hex-formatted straight into the retained head buffer, so echoing the
/// ID on the inference fast path stays allocation-free.
pub fn write_head_with_trace(
    head: &mut Vec<u8>,
    status: u16,
    content_type: &str,
    body_len: usize,
    keep_alive: bool,
    trace_id: u64,
) {
    use std::io::Write as _;
    head.clear();
    let _ = write!(
        head,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nx-trace-id: {:016x}\r\nconnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        body_len,
        trace_id,
        if keep_alive { "keep-alive" } else { "close" },
    );
}

/// Canonical reason phrase for the statuses the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// An outbound response. `Content-Length` and `Connection` are written by
/// [`Response::write_to`]; other headers accumulate via [`Response::with_header`].
#[derive(Debug)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// Extra headers (Content-Length/Connection are added on write).
    pub headers: Vec<(String, String)>,
    /// Response body.
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response with `content-type: application/json`.
    pub fn json(status: u16, body: &crate::util::json::Json) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), "application/json".into())],
            body: body.to_string().into_bytes(),
        }
    }

    /// Plain-text response.
    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), "text/plain; charset=utf-8".into())],
            body: body.as_bytes().to_vec(),
        }
    }

    /// Builder-style extra header.
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    /// Serialize to the wire with explicit framing headers.
    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<()> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, reason(self.status));
        for (k, v) in &self.headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("content-length: {}\r\n", self.body.len()));
        head.push_str(if keep_alive {
            "connection: keep-alive\r\n\r\n"
        } else {
            "connection: close\r\n\r\n"
        });
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

// ---------------------------------------------------------------------------
// Client side (load generator, tests)
// ---------------------------------------------------------------------------

/// Write one request with a length-delimited body.
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> std::io::Result<()> {
    let mut head = format!("{method} {path} HTTP/1.1\r\n");
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// A parsed response on the client side.
#[derive(Debug)]
pub struct ClientResponse {
    /// Status code.
    pub status: u16,
    /// Headers in arrival order, names lowercased.
    pub headers: Vec<(String, String)>,
    /// Length-delimited body.
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        find_header(&self.headers, name)
    }

    /// Body as UTF-8 (empty string when invalid).
    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }

    /// Whether the server will keep the connection open (`Connection` is
    /// a token list: `close, x-foo` counts as close).
    pub fn keep_alive(&self) -> bool {
        !connection_has_token(self.header("connection").unwrap_or(""), "close")
    }
}

/// Read one response (status line, headers, length-delimited body) with
/// the default stall budget.
pub fn read_response<R: BufRead>(r: &mut R) -> Result<ClientResponse, HttpError> {
    read_response_within(r, STALL_DEADLINE)
}

/// [`read_response`] with a caller-supplied stall budget — the retry loop
/// around short socket timeouts gives up after `stall`, so clients with a
/// configured per-request timeout are actually bounded by it.
pub fn read_response_within<R: BufRead>(
    r: &mut R,
    stall: Duration,
) -> Result<ClientResponse, HttpError> {
    let deadline = Instant::now() + stall;
    let mut line = String::new();
    match read_line_retry(r, &mut line, false, deadline)? {
        LineRead::Line => {}
        _ => return Err(HttpError::Io("connection closed before response".into())),
    }
    let trimmed = line.trim_end_matches(['\r', '\n']);
    let mut parts = trimmed.splitn(3, ' ');
    let version = parts.next().unwrap_or("");
    let status = parts
        .next()
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| HttpError::Malformed(format!("bad status line '{trimmed}'")))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("bad status line '{trimmed}'")));
    }
    let headers = read_headers(r, deadline)?;
    let len = content_length(&headers)?;
    let body = read_body(r, len, deadline)?;
    Ok(ClientResponse {
        status,
        headers,
        body,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<ReadOutcome, HttpError> {
        let mut c = Cursor::new(raw.as_bytes().to_vec());
        read_request(&mut c, 1 << 20)
    }

    fn must_request(raw: &str) -> Request {
        match parse(raw).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("expected request, got {other:?}"),
        }
    }

    #[test]
    fn parses_post_with_body() {
        let req = must_request(
            "POST /v1/infer HTTP/1.1\r\ncontent-type: application/json\r\ncontent-length: 4\r\n\r\nabcd",
        );
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer");
        assert_eq!(req.header("Content-Type"), Some("application/json"));
        assert_eq!(req.body, b"abcd");
        assert!(req.wants_keep_alive());
    }

    #[test]
    fn parses_get_without_body_and_strips_query() {
        let req = must_request("GET /metrics?verbose=1 HTTP/1.1\r\n\r\n");
        assert_eq!(req.route_path(), "/metrics");
        assert!(req.body.is_empty());
    }

    #[test]
    fn bare_lf_lines_accepted() {
        let req = must_request("GET /healthz HTTP/1.1\nhost: x\n\n");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let req = must_request("GET / HTTP/1.1\r\nconnection: close\r\n\r\n");
        assert!(!req.wants_keep_alive());
        let req = must_request("GET / HTTP/1.0\r\n\r\n");
        assert!(!req.wants_keep_alive(), "1.0 defaults to close");
        let req = must_request("GET / HTTP/1.0\r\nconnection: keep-alive\r\n\r\n");
        assert!(req.wants_keep_alive());
    }

    #[test]
    fn connection_token_list_close_disables_keep_alive() {
        // `Connection` is a comma-separated token list: `close, x-foo` is
        // a close, and a token that merely *contains* "close" is not.
        let req = must_request("GET / HTTP/1.1\r\nconnection: close, x-foo\r\n\r\n");
        assert!(!req.wants_keep_alive());
        let req = must_request("GET / HTTP/1.1\r\nconnection: x-foo , CLOSE\r\n\r\n");
        assert!(!req.wants_keep_alive());
        let req = must_request("GET / HTTP/1.1\r\nconnection: not-close\r\n\r\n");
        assert!(req.wants_keep_alive());
        let req = must_request("GET / HTTP/1.0\r\nconnection: keep-alive, upgrade\r\n\r\n");
        assert!(req.wants_keep_alive());
        // Scratch parser shares the token-list fix.
        let mut c = Cursor::new(b"GET / HTTP/1.1\r\nconnection: close, x-foo\r\n\r\n".to_vec());
        let mut s = RequestScratch::new();
        assert_eq!(
            read_request_reusing(&mut c, 1 << 20, &mut s).unwrap(),
            ScratchOutcome::Request
        );
        assert!(!s.wants_keep_alive());
    }

    #[test]
    fn duplicate_content_length_is_rejected() {
        // Request smuggling guard: two Content-Length headers (even with
        // equal values) must be refused, not first-match-wins.
        assert!(matches!(
            parse("POST / HTTP/1.1\r\ncontent-length: 4\r\ncontent-length: 4\r\n\r\nabcd"),
            Err(HttpError::Malformed(m)) if m.contains("duplicate content-length")
        ));
        assert!(matches!(
            parse("POST / HTTP/1.1\r\ncontent-length: 4\r\nContent-Length: 9\r\n\r\nabcd"),
            Err(HttpError::Malformed(m)) if m.contains("duplicate content-length")
        ));
        // The client-side response parser enforces the same rule.
        let wire = b"HTTP/1.1 200 OK\r\ncontent-length: 2\r\ncontent-length: 2\r\n\r\nhi".to_vec();
        let mut c = Cursor::new(wire);
        assert!(matches!(
            read_response(&mut c),
            Err(HttpError::Malformed(m)) if m.contains("duplicate content-length")
        ));
    }

    #[test]
    fn frame_scan_tracks_the_parser() {
        let full = b"POST / HTTP/1.1\r\ncontent-length: 4\r\n\r\nabcd";
        let head_end = full.len() - 4;
        assert_eq!(scan_request_frame(full, 1 << 20), FrameScan::Ready);
        // Every strict prefix is not-yet-Ready: Partial while the head
        // is incomplete, NeedBody(total) once it is.
        for cut in 1..full.len() {
            let want = if cut < head_end {
                FrameScan::Partial
            } else {
                FrameScan::NeedBody(full.len())
            };
            assert_eq!(scan_request_frame(&full[..cut], 1 << 20), want, "cut at {cut}");
        }
        // No body: ready at the blank line, partial before it.
        assert_eq!(
            scan_request_frame(b"GET / HTTP/1.1\r\n\r\n", 1 << 20),
            FrameScan::Ready
        );
        assert_eq!(
            scan_request_frame(b"GET / HTTP/1.1\r\n", 1 << 20),
            FrameScan::Partial
        );
        // Bare-LF framing counts too.
        assert_eq!(
            scan_request_frame(b"GET / HTTP/1.1\nhost: x\n\n", 1 << 20),
            FrameScan::Ready
        );
        // Anomalies are Ready — the parser owns the verdict: duplicate
        // content-length, bad value, chunked, over-cap body, blank first
        // line, colonless header.
        for anomaly in [
            &b"POST / HTTP/1.1\r\ncontent-length: 4\r\ncontent-length: 4\r\n\r\n"[..],
            b"POST / HTTP/1.1\r\ncontent-length: nope\r\n\r\n",
            b"POST / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n",
            b"\r\nGET / HTTP/1.1\r\n\r\n",
            b"GET / HTTP/1.1\r\nnocolon\r\n\r\n",
        ] {
            assert_eq!(
                scan_request_frame(anomaly, 1 << 20),
                FrameScan::Ready,
                "{}",
                String::from_utf8_lossy(anomaly)
            );
        }
        // Over-cap declared body is Ready without waiting for the bytes
        // (the parser answers 413 from the head alone).
        assert_eq!(
            scan_request_frame(b"POST / HTTP/1.1\r\ncontent-length: 999\r\n\r\n", 10),
            FrameScan::Ready
        );
        // A peer streaming a newline-free line is Ready once the parser
        // is committed to "line too long".
        let mut endless = b"GET /".to_vec();
        endless.extend(std::iter::repeat(b'a').take(MAX_LINE_BYTES + 8));
        assert_eq!(scan_request_frame(&endless, 1 << 20), FrameScan::Ready);
        assert_eq!(
            scan_request_frame(&endless[..MAX_LINE_BYTES], 1 << 20),
            FrameScan::Partial
        );
    }

    #[test]
    fn eof_at_boundary_is_clean() {
        assert!(matches!(parse("").unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn rejects_garbage_and_oversize() {
        assert!(matches!(
            parse("NONSENSE\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\ncontent-length: nope\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        let mut c = Cursor::new(b"POST / HTTP/1.1\r\ncontent-length: 99\r\n\r\n".to_vec());
        assert!(matches!(
            read_request(&mut c, 10),
            Err(HttpError::BodyTooLarge(99))
        ));
    }

    #[test]
    fn endless_request_line_is_rejected_not_buffered() {
        // A peer streaming bytes with no newline must hit the line cap,
        // not grow the buffer indefinitely.
        let mut raw = b"GET /".to_vec();
        raw.extend(std::iter::repeat(b'a').take(MAX_HEADER_BYTES * 4));
        let mut c = Cursor::new(raw);
        assert!(matches!(
            read_request(&mut c, 1 << 20),
            Err(HttpError::Malformed(m)) if m.contains("too long")
        ));
    }

    #[test]
    fn truncated_body_is_io_error() {
        assert!(matches!(
            parse("POST / HTTP/1.1\r\ncontent-length: 10\r\n\r\nabc"),
            Err(HttpError::Io(_))
        ));
    }

    #[test]
    fn response_roundtrips_through_client_parser() {
        let resp = Response::json(429, &crate::util::json::Json::Null)
            .with_header("retry-after", "2");
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let mut c = Cursor::new(wire);
        let parsed = read_response(&mut c).unwrap();
        assert_eq!(parsed.status, 429);
        assert_eq!(parsed.header("Retry-After"), Some("2"));
        assert_eq!(parsed.body_str(), "null");
        assert!(parsed.keep_alive());
    }

    #[test]
    fn response_connection_close_is_signalled() {
        let mut wire = Vec::new();
        Response::text(200, "hi").write_to(&mut wire, false).unwrap();
        let mut c = Cursor::new(wire);
        let parsed = read_response(&mut c).unwrap();
        assert!(!parsed.keep_alive());
        assert_eq!(parsed.body_str(), "hi");
    }

    #[test]
    fn request_writer_roundtrips_through_request_parser() {
        let mut wire = Vec::new();
        write_request(
            &mut wire,
            "POST",
            "/v1/infer",
            &[("content-type", "application/json")],
            b"{\"features\":[1]}",
        )
        .unwrap();
        let mut c = Cursor::new(wire);
        let req = match read_request(&mut c, 1 << 20).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("{other:?}"),
        };
        assert_eq!(req.body, b"{\"features\":[1]}");
    }

    #[test]
    fn scratch_reader_matches_allocating_reader_and_reuses_buffers() {
        let raw = "POST /v1/infer HTTP/1.1\r\ncontent-type: application/json\r\ncontent-length: 4\r\n\r\nabcd\
                   GET /metrics?x=1 HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut c = Cursor::new(raw.as_bytes().to_vec());
        let mut s = RequestScratch::new();
        assert_eq!(
            read_request_reusing(&mut c, 1 << 20, &mut s).unwrap(),
            ScratchOutcome::Request
        );
        assert_eq!(s.method, "POST");
        assert_eq!(s.route_path(), "/v1/infer");
        assert_eq!(s.header("Content-Type"), Some("application/json"));
        assert_eq!(s.body, b"abcd");
        assert!(s.wants_keep_alive());
        // Second request reuses the same scratch; stale headers/body from
        // the first must not leak through.
        assert_eq!(
            read_request_reusing(&mut c, 1 << 20, &mut s).unwrap(),
            ScratchOutcome::Request
        );
        assert_eq!(s.method, "GET");
        assert_eq!(s.route_path(), "/metrics");
        assert_eq!(s.header("content-type"), None, "stale header leaked");
        assert!(s.body.is_empty());
        assert!(!s.wants_keep_alive());
        assert_eq!(
            read_request_reusing(&mut c, 1 << 20, &mut s).unwrap(),
            ScratchOutcome::Eof
        );
    }

    #[test]
    fn scratch_reader_rejects_oversize_and_garbage() {
        let mut s = RequestScratch::new();
        let mut c = Cursor::new(b"POST / HTTP/1.1\r\ncontent-length: 99\r\n\r\n".to_vec());
        assert!(matches!(
            read_request_reusing(&mut c, 10, &mut s),
            Err(HttpError::BodyTooLarge(99))
        ));
        let mut c = Cursor::new(b"NONSENSE\r\n\r\n".to_vec());
        assert!(matches!(
            read_request_reusing(&mut c, 1 << 20, &mut s),
            Err(HttpError::Malformed(_))
        ));
    }

    #[test]
    fn write_head_roundtrips_through_client_parser() {
        let mut head = Vec::new();
        write_head(&mut head, 200, "application/json", 2, true);
        let mut wire = head.clone();
        wire.extend_from_slice(b"[]");
        let mut c = Cursor::new(wire);
        let parsed = read_response(&mut c).unwrap();
        assert_eq!(parsed.status, 200);
        assert_eq!(parsed.header("content-type"), Some("application/json"));
        assert_eq!(parsed.body_str(), "[]");
        assert!(parsed.keep_alive());
        // Reuse clears the previous head.
        write_head(&mut head, 503, "text/plain", 0, false);
        let s = String::from_utf8(head.clone()).unwrap();
        assert!(s.starts_with("HTTP/1.1 503"), "{s}");
        assert!(s.contains("connection: close"));
    }

    #[test]
    fn write_head_with_trace_carries_hex_trace_id() {
        let mut head = Vec::new();
        write_head_with_trace(&mut head, 200, "application/json", 2, true, 0xab);
        let mut wire = head.clone();
        wire.extend_from_slice(b"[]");
        let mut c = Cursor::new(wire);
        let parsed = read_response(&mut c).unwrap();
        assert_eq!(parsed.status, 200);
        assert_eq!(parsed.header("X-Trace-Id"), Some("00000000000000ab"));
        assert_eq!(parsed.body_str(), "[]");
    }

    #[test]
    fn two_pipelined_requests_parse_sequentially() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n";
        let mut c = Cursor::new(raw.as_bytes().to_vec());
        let a = match read_request(&mut c, 1 << 20).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("{other:?}"),
        };
        let b = match read_request(&mut c, 1 << 20).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("{other:?}"),
        };
        assert_eq!(a.path, "/healthz");
        assert_eq!(b.path, "/metrics");
        assert!(matches!(
            read_request(&mut c, 1 << 20).unwrap(),
            ReadOutcome::Eof
        ));
    }
}
