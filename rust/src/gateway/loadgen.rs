//! Closed- and open-loop load generator for the gateway.
//!
//! *Closed* mode models a fixed client population: each of `concurrency`
//! workers keeps exactly one request outstanding on a persistent
//! keep-alive connection, so offered load adapts to service rate (the
//! classic closed-loop throughput probe). *Open* mode paces request
//! starts at `rate / concurrency` per worker; each worker still waits
//! for its response before the next send, so the achievable offered load
//! is bounded by `concurrency / latency` — size `concurrency ≳ rps ×
//! expected latency` (with headroom) to approximate a true open loop and
//! expose queueing collapse and shed behaviour past saturation.
//!
//! The request-size mix cycles through `rows_mix` (rows per request), and
//! the report carries exact p50/p95/p99 latency over every successful
//! request plus shed/error tallies and goodput, renderable as text or
//! JSON.
//!
//! Latency is reported twice. The *raw* percentiles measure from the
//! moment each request was actually written. Under open-loop pacing that
//! systematically under-reports server trouble: a synchronous worker that
//! is stuck waiting on a slow response cannot fire the arrivals it was
//! scheduled to fire, so exactly the requests that would have seen the
//! congestion are silently omitted (coordinated omission). The
//! *corrected* percentiles therefore measure each request from its
//! **intended** send time on the arrival schedule — generator stall
//! counts against the server, and `corrected >= raw` always holds. In
//! closed mode there is no schedule and the two sets coincide.
//!
//! With `binary: true` the generator speaks the gateway's length-prefixed
//! [`wire`] frame (`Content-Type: application/x-acdc-f32`) instead of
//! JSON, exercising the zero-parse fast path.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::{http, wire};
use crate::util::bench::percentile;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;

/// Arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalMode {
    /// One outstanding request per worker (offered load = service rate).
    Closed,
    /// Paced arrivals targeting this aggregate rate (requests/second).
    /// Workers are synchronous, so the rate is only reachable while
    /// `concurrency / latency` exceeds it; see the module docs.
    Open {
        /// Aggregate target rate, requests/second.
        rps: f64,
    },
}

/// Load generator configuration.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Gateway address, e.g. `"127.0.0.1:7878"`.
    pub addr: String,
    /// Additional target addresses for cluster runs (`--targets`).
    /// Empty means "just `addr`"; otherwise workers are spread
    /// round-robin across this list (worker *i* owns `targets[i % len]`)
    /// and a worker whose target stops connecting rotates to the next
    /// address, so a killed shard degrades throughput instead of
    /// idling a worker. Percentile math is unchanged — including the
    /// coordinated-omission-corrected set.
    pub targets: Vec<String>,
    /// Arrival process (closed or open loop).
    pub mode: ArrivalMode,
    /// Worker threads (each with its own keep-alive connection).
    pub concurrency: usize,
    /// Wall-clock run length.
    pub duration: Duration,
    /// Model input width N (features per row).
    pub width: usize,
    /// Rows-per-request mix, cycled per request (e.g. `[1, 1, 8]`).
    pub rows_mix: Vec<usize>,
    /// Socket/request timeout.
    pub timeout: Duration,
    /// RNG seed for the feature payloads.
    pub seed: u64,
    /// Send the binary [`wire`] frame instead of JSON bodies.
    pub binary: bool,
    /// Per-request deadline budget sent as `x-acdc-deadline-ms`. `None`
    /// leaves the header off, so the gateway applies its configured
    /// default. Responses with status 504 (budget exhausted server-side)
    /// are tallied separately from sheds and transport errors.
    pub deadline_ms: Option<u64>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7878".into(),
            targets: Vec::new(),
            mode: ArrivalMode::Closed,
            concurrency: 8,
            duration: Duration::from_secs(5),
            width: 256,
            rows_mix: vec![1],
            timeout: Duration::from_secs(5),
            seed: 0,
            binary: false,
            deadline_ms: None,
        }
    }
}

impl LoadgenConfig {
    /// Sanity-check concurrency/width/mix/rate.
    pub fn validate(&self) -> Result<(), String> {
        if self.concurrency == 0 {
            return Err("loadgen concurrency must be >= 1".into());
        }
        if self.width == 0 {
            return Err("loadgen width must be >= 1".into());
        }
        if self.rows_mix.is_empty() || self.rows_mix.contains(&0) {
            return Err("rows mix must be non-empty positive row counts".into());
        }
        if self.targets.iter().any(|t| t.is_empty()) {
            return Err("loadgen targets must not contain empty addresses".into());
        }
        if let ArrivalMode::Open { rps } = self.mode {
            if !rps.is_finite() || rps <= 0.0 {
                return Err("open-loop rate must be a positive number".into());
            }
        }
        if self.deadline_ms == Some(0) {
            return Err("deadline must be >= 1 millisecond".into());
        }
        Ok(())
    }
}

/// Aggregate results of one run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests sent (including shed/errored ones).
    pub sent: u64,
    /// 200 responses.
    pub ok: u64,
    /// 429/503 shed responses.
    pub shed: u64,
    /// 504 responses — the request's deadline budget ran out server-side
    /// (reaped in queue, stale at the worker, or refused on the router's
    /// budget gate). Kept apart from `shed` and `errors` because it is
    /// the signal the deadline experiments assert on.
    pub deadline_exceeded: u64,
    /// Transport failures and non-shed, non-deadline error statuses.
    pub errors: u64,
    /// Feature rows carried by successful requests.
    pub rows_ok: u64,
    /// Wall-clock run time in seconds.
    pub wall_s: f64,
    /// Median latency of successful requests, milliseconds.
    pub p50_ms: f64,
    /// 95th-percentile latency, milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile latency, milliseconds.
    pub p99_ms: f64,
    /// Mean latency, milliseconds.
    pub mean_ms: f64,
    /// Worst latency, milliseconds.
    pub max_ms: f64,
    /// Coordinated-omission-corrected median (from intended send time).
    pub corrected_p50_ms: f64,
    /// Coordinated-omission-corrected 95th percentile.
    pub corrected_p95_ms: f64,
    /// Coordinated-omission-corrected 99th percentile.
    pub corrected_p99_ms: f64,
}

impl LoadReport {
    /// Request attempts per second — offered load, including attempts
    /// that never got a response (failed connects, transport errors).
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.sent as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Successful requests per second.
    pub fn goodput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.ok as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// The report as a JSON document.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("deadline_exceeded", Json::Num(self.deadline_exceeded as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("rows_ok", Json::Num(self.rows_ok as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("goodput_rps", Json::Num(self.goodput_rps())),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("max_ms", Json::Num(self.max_ms)),
            ("corrected_p50_ms", Json::Num(self.corrected_p50_ms)),
            ("corrected_p95_ms", Json::Num(self.corrected_p95_ms)),
            ("corrected_p99_ms", Json::Num(self.corrected_p99_ms)),
        ])
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        format!(
            "loadgen: sent {} | ok {} | shed {} | deadline-exceeded {} | errors {} | rows {}\n\
             wall {:.2}s  throughput {:.0} req/s  goodput {:.0} req/s\n\
             latency ms: p50 {:.2}  p95 {:.2}  p99 {:.2}  mean {:.2}  max {:.2}\n\
             corrected ms (from intended send): p50 {:.2}  p95 {:.2}  p99 {:.2}\n",
            self.sent,
            self.ok,
            self.shed,
            self.deadline_exceeded,
            self.errors,
            self.rows_ok,
            self.wall_s,
            self.throughput_rps(),
            self.goodput_rps(),
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_ms,
            self.max_ms,
            self.corrected_p50_ms,
            self.corrected_p95_ms,
            self.corrected_p99_ms,
        )
    }
}

#[derive(Default)]
struct WorkerStats {
    sent: u64,
    ok: u64,
    shed: u64,
    deadline_exceeded: u64,
    errors: u64,
    rows_ok: u64,
    latencies_ms: Vec<f64>,
    corrected_ms: Vec<f64>,
}

/// Coordinated-omission-corrected latency for one request: measured from
/// the *intended* send time on the arrival schedule rather than the
/// actual write, so generator stall (a worker wedged behind a slow
/// response) counts against the server instead of vanishing. Clamps to
/// zero if the schedule ran ahead of the clock.
fn corrected_latency_ms(intended: Instant, completed: Instant) -> f64 {
    completed.saturating_duration_since(intended).as_secs_f64() * 1e3
}

/// Drive the gateway; blocks for `cfg.duration` and returns the report.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    cfg.validate()?;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..cfg.concurrency)
        .map(|wi| {
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("acdc-loadgen-{wi}"))
                .spawn(move || worker(&cfg, wi))
                .map_err(|e| format!("spawn loadgen worker: {e}"))
        })
        .collect::<Result<_, String>>()?;
    let mut stats = WorkerStats::default();
    for h in handles {
        let w = h.join().map_err(|_| "loadgen worker panicked".to_string())?;
        stats.sent += w.sent;
        stats.ok += w.ok;
        stats.shed += w.shed;
        stats.deadline_exceeded += w.deadline_exceeded;
        stats.errors += w.errors;
        stats.rows_ok += w.rows_ok;
        stats.latencies_ms.extend(w.latencies_ms);
        stats.corrected_ms.extend(w.corrected_ms);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let mut lats = stats.latencies_ms;
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut corr = stats.corrected_ms;
    corr.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if lats.is_empty() {
        0.0
    } else {
        lats.iter().sum::<f64>() / lats.len() as f64
    };
    // percentile() yields NaN on empty input, which would poison the JSON
    // report — an all-shed run reports zeros instead.
    let pct = |p: f64| if lats.is_empty() { 0.0 } else { percentile(&lats, p) };
    let cpct = |p: f64| if corr.is_empty() { 0.0 } else { percentile(&corr, p) };
    Ok(LoadReport {
        sent: stats.sent,
        ok: stats.ok,
        shed: stats.shed,
        deadline_exceeded: stats.deadline_exceeded,
        errors: stats.errors,
        rows_ok: stats.rows_ok,
        wall_s,
        p50_ms: pct(50.0),
        p95_ms: pct(95.0),
        p99_ms: pct(99.0),
        mean_ms: mean,
        max_ms: lats.last().copied().unwrap_or(0.0),
        corrected_p50_ms: cpct(50.0),
        corrected_p95_ms: cpct(95.0),
        corrected_p99_ms: cpct(99.0),
    })
}

fn worker(cfg: &LoadgenConfig, wi: usize) -> WorkerStats {
    let mut rng = Pcg32::seeded(cfg.seed.wrapping_add(wi as u64 * 7919 + 1));
    let mut stats = WorkerStats::default();
    // Cluster runs spread workers round-robin over `targets`; a worker
    // rotates to the next address when its target stops connecting.
    let targets: &[String] = if cfg.targets.is_empty() {
        std::slice::from_ref(&cfg.addr)
    } else {
        &cfg.targets
    };
    let mut target_at = wi % targets.len();
    let deadline = Instant::now() + cfg.duration;
    let interval = match cfg.mode {
        ArrivalMode::Closed => None,
        ArrivalMode::Open { rps } => Some(Duration::from_secs_f64(
            cfg.concurrency as f64 / rps,
        )),
    };
    // Stagger workers across one pacing interval so open-loop arrivals
    // spread evenly instead of firing in synchronized bursts.
    let mut next_fire = match interval {
        Some(iv) => Instant::now() + iv.mul_f64(wi as f64 / cfg.concurrency as f64),
        None => Instant::now(),
    };
    let mut conn: Option<(TcpStream, BufReader<TcpStream>)> = None;
    let mut mix_at = wi; // stagger the mix cycle across workers
    // Reused payload buffers: every request body renders into one
    // retained String (JSON) or f32/byte pair (binary), so payload
    // generation stops allocating once the largest mix entry has been
    // seen.
    let mut body = String::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut frame: Vec<u8> = Vec::new();
    // Rendered once: the deadline budget is the same on every request.
    let deadline_hdr = cfg.deadline_ms.map(|ms| ms.to_string());
    while Instant::now() < deadline {
        // The *intended* send time of this arrival. Open loop: the
        // scheduled fire instant, captured before the schedule advances —
        // the anchor for coordinated-omission correction. Closed loop: no
        // schedule exists, so the actual send time is the anchor and the
        // corrected percentiles coincide with the raw ones.
        let intended = if let Some(iv) = interval {
            let now = Instant::now();
            if now < next_fire {
                std::thread::sleep(next_fire - now);
            }
            let at = next_fire;
            // Schedule the next arrival independently of completion time
            // (back-to-back catch-up when the previous request overran).
            next_fire += iv;
            Some(at)
        } else {
            None
        };
        let rows = cfg.rows_mix[mix_at % cfg.rows_mix.len()];
        mix_at += 1;
        let (payload, content_type): (&[u8], &str) = if cfg.binary {
            vals.clear();
            for _ in 0..rows * cfg.width {
                vals.push(rng.normal_with(0.0, 1.0) as f32);
            }
            wire::write_binary_request(&mut frame, cfg.width, &vals);
            (&frame, wire::CONTENT_TYPE)
        } else {
            render_body_into(&mut body, rows, cfg.width, &mut rng);
            (body.as_bytes(), "application/json")
        };
        if conn.is_none() {
            conn = connect(&targets[target_at], cfg.timeout);
            if conn.is_none() {
                stats.sent += 1;
                stats.errors += 1;
                target_at = (target_at + 1) % targets.len();
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        }
        let (stream, reader) = conn.as_mut().unwrap();
        stats.sent += 1;
        let t = Instant::now();
        let mut headers: Vec<(&str, &str)> = vec![("content-type", content_type)];
        if let Some(ms) = deadline_hdr.as_deref() {
            headers.push(("x-acdc-deadline-ms", ms));
        }
        let wrote = http::write_request(stream, "POST", "/v1/infer", &headers, payload);
        if wrote.is_err() {
            stats.errors += 1;
            conn = None;
            continue;
        }
        match http::read_response_within(reader, cfg.timeout) {
            Ok(resp) => {
                match resp.status {
                    200 => {
                        let done = Instant::now();
                        stats.ok += 1;
                        stats.rows_ok += rows as u64;
                        stats
                            .latencies_ms
                            .push(done.duration_since(t).as_secs_f64() * 1e3);
                        let anchor = intended.unwrap_or(t);
                        stats.corrected_ms.push(corrected_latency_ms(anchor, done));
                    }
                    429 | 503 => stats.shed += 1,
                    504 => stats.deadline_exceeded += 1,
                    _ => stats.errors += 1,
                }
                if !resp.keep_alive() {
                    conn = None;
                }
            }
            Err(_) => {
                stats.errors += 1;
                conn = None;
            }
        }
    }
    stats
}

fn connect(addr: &str, timeout: Duration) -> Option<(TcpStream, BufReader<TcpStream>)> {
    // connect_timeout so a blackholed/saturated gateway cannot park a
    // worker in the OS connect far past the configured run duration.
    let resolved = addr.to_socket_addrs().ok()?.next()?;
    let stream = TcpStream::connect_timeout(&resolved, timeout).ok()?;
    stream.set_read_timeout(Some(timeout)).ok()?;
    // A write timeout too: a wedged peer that stops reading would
    // otherwise park the worker in write_request past the run deadline.
    stream.set_write_timeout(Some(timeout)).ok()?;
    stream.set_nodelay(true).ok()?;
    let reader = BufReader::new(stream.try_clone().ok()?);
    Some((stream, reader))
}

/// JSON body for one request: `features` for a single row, `rows` batch
/// otherwise.
fn request_body(rows: usize, width: usize, rng: &mut Pcg32) -> String {
    let mut out = String::new();
    render_body_into(&mut out, rows, width, rng);
    out
}

/// Render one request body into a reused buffer — no `Json` tree, no
/// per-request String (the canonical shapes the gateway's fast parser
/// consumes without touching its own DOM parser).
fn render_body_into(buf: &mut String, rows: usize, width: usize, rng: &mut Pcg32) {
    use std::fmt::Write as _;
    buf.clear();
    let mut row = |buf: &mut String, rng: &mut Pcg32| {
        buf.push('[');
        for i in 0..width {
            if i > 0 {
                buf.push(',');
            }
            let v = rng.normal_with(0.0, 1.0) as f32;
            let _ = write!(buf, "{v}");
        }
        buf.push(']');
    };
    if rows == 1 {
        buf.push_str("{\"features\":");
        row(buf, rng);
    } else {
        buf.push_str("{\"rows\":[");
        for r in 0..rows {
            if r > 0 {
                buf.push(',');
            }
            row(buf, rng);
        }
        buf.push(']');
    }
    buf.push('}');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        assert!(LoadgenConfig::default().validate().is_ok());
        let bad = LoadgenConfig {
            concurrency: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = LoadgenConfig {
            rows_mix: vec![1, 0],
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = LoadgenConfig {
            mode: ArrivalMode::Open { rps: 0.0 },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // Multi-target lists are fine; empty addresses inside one are not.
        let ok = LoadgenConfig {
            targets: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        let bad = LoadgenConfig {
            targets: vec!["127.0.0.1:1".into(), String::new()],
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // A zero deadline could never be met; require at least 1ms.
        let bad = LoadgenConfig {
            deadline_ms: Some(0),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let ok = LoadgenConfig {
            deadline_ms: Some(50),
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn request_bodies_match_the_wire_contract() {
        let mut rng = Pcg32::seeded(1);
        let single = Json::parse(&request_body(1, 4, &mut rng)).unwrap();
        assert_eq!(single.get("features").unwrap().as_arr().unwrap().len(), 4);
        let batch = Json::parse(&request_body(3, 4, &mut rng)).unwrap();
        let rows = batch.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].as_arr().unwrap().len(), 4);
    }

    #[test]
    fn report_rates_and_json() {
        let r = LoadReport {
            sent: 100,
            ok: 80,
            shed: 12,
            deadline_exceeded: 3,
            errors: 5,
            rows_ok: 80,
            wall_s: 2.0,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
            mean_ms: 1.2,
            max_ms: 4.0,
            corrected_p50_ms: 1.5,
            corrected_p95_ms: 9.0,
            corrected_p99_ms: 42.0,
        };
        assert!((r.throughput_rps() - 50.0).abs() < 1e-9);
        assert!((r.goodput_rps() - 40.0).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(j.get("shed").unwrap().as_f64(), Some(12.0));
        assert_eq!(j.get("deadline_exceeded").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("p99_ms").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("corrected_p99_ms").unwrap().as_f64(), Some(42.0));
        assert!(r.render().contains("goodput 40"));
        assert!(r.render().contains("deadline-exceeded 3"));
        assert!(r.render().contains("corrected ms"));
    }

    #[test]
    fn corrected_latency_counts_generator_stall() {
        // A request that was *scheduled* 40ms before it was actually
        // written, then served in 10ms: raw latency says 10ms, corrected
        // says 50ms — the stall the generator coordinated away.
        let intended = Instant::now();
        let sent = intended + Duration::from_millis(40);
        let done = sent + Duration::from_millis(10);
        let raw = done.duration_since(sent).as_secs_f64() * 1e3;
        let corrected = corrected_latency_ms(intended, done);
        assert!(corrected >= raw, "corrected must dominate raw");
        assert!((corrected - 50.0).abs() < 1.0);
        // When the anchor IS the send time (closed loop), they coincide.
        assert!((corrected_latency_ms(sent, done) - raw).abs() < 1e-9);
        // A schedule that ran ahead of the clock clamps to zero rather
        // than going negative.
        assert_eq!(corrected_latency_ms(done, intended), 0.0);
    }

    #[test]
    fn binary_bodies_match_the_wire_contract() {
        let mut rng = Pcg32::seeded(7);
        let mut vals: Vec<f32> = Vec::new();
        for _ in 0..3 * 4 {
            vals.push(rng.normal_with(0.0, 1.0) as f32);
        }
        let mut frame = Vec::new();
        wire::write_binary_request(&mut frame, 4, &vals);
        let mut parsed = Vec::new();
        let rows = wire::parse_binary_request(&frame, 4, 64, &mut parsed).unwrap();
        assert_eq!(rows, 3);
        assert_eq!(parsed, vals);
    }

    #[test]
    fn run_against_nothing_reports_errors_not_panics() {
        // Port 9 (discard) on localhost is almost certainly closed; every
        // request must surface as a transport error.
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:9".into(),
            concurrency: 2,
            duration: Duration::from_millis(100),
            width: 4,
            timeout: Duration::from_millis(200),
            ..Default::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.ok, 0);
        assert!(report.errors > 0);
    }
}
