//! §5 performance model: arithmetic intensity and roofline curves.
//!
//! The paper's Figure 2 plots measured runtimes against "peak" curves
//! derived from a Titan X's 6605 GFLOP/s and 336.5 GB/s. This module
//! reproduces that model exactly — FLOP counts, bytes moved, arithmetic
//! intensity AI = (4 + 5·log2 N)/8 — parameterized by the hardware so the
//! same curves can be drawn for the paper's GPU and for this testbed
//! (DESIGN.md substitution S1).

/// Hardware roofline parameters.
#[derive(Debug, Clone, Copy)]
pub struct Hardware {
    /// Human-readable device name.
    pub name: &'static str,
    /// Peak floating-point throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak memory bandwidth, bytes/s.
    pub peak_bw: f64,
}

impl Hardware {
    /// The paper's benchmark processor (§5).
    pub const TITAN_X: Hardware = Hardware {
        name: "NVIDIA Titan X",
        peak_flops: 6605e9,
        peak_bw: 336.5e9,
    };

    /// Machine-balance point in FLOPs/byte ("approximately 20" in §5).
    pub fn balance(&self) -> f64 {
        self.peak_flops / self.peak_bw
    }

    /// Roofline-predicted seconds for (flops, bytes): whichever of the
    /// compute or memory legs dominates.
    pub fn predict_seconds(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.peak_flops).max(bytes / self.peak_bw)
    }

    /// Measure this host's achievable memory bandwidth with a large
    /// read+write streaming pass (a tiny STREAM-triad). Used to draw the
    /// testbed's own peak curves.
    pub fn measure_host(samples: usize) -> Hardware {
        let n = 1 << 24; // 16M f32 = 64 MiB, beyond LLC
        let mut a = vec![1.0f32; n];
        let b = vec![2.0f32; n];
        let mut best_bw = 0.0f64;
        for _ in 0..samples.max(1) {
            let t = std::time::Instant::now();
            for i in 0..n {
                a[i] = a[i] + 1.5 * b[i];
            }
            let secs = t.elapsed().as_secs_f64();
            std::hint::black_box(&a);
            // triad moves 3 words per element (2 loads + 1 store)
            let bytes = 3.0 * 4.0 * n as f64;
            best_bw = best_bw.max(bytes / secs);
        }
        Hardware {
            name: "host (measured triad)",
            // 2 flops per element at measured bandwidth — crude but only
            // the BW leg matters for ACDC's memory-bound regime.
            peak_flops: best_bw / 4.0 * 2.0,
            peak_bw: best_bw,
        }
    }
}

/// FLOPs of one ACDC layer forward for a batch (paper §5):
/// ≈ (4N + 5N·log2 N) per example.
pub fn acdc_flops(n: usize, batch: usize) -> f64 {
    let nf = n as f64;
    batch as f64 * (4.0 * nf + 5.0 * nf * nf.log2())
}

/// Minimum bytes to/from main memory for a batched ACDC layer (§5):
/// 8 bytes/element (4 in + 4 out) once A/D are cached across the batch.
pub fn acdc_bytes_batched(n: usize, batch: usize) -> f64 {
    8.0 * (n * batch) as f64
}

/// Bytes for a single example including the A and D loads (§5's 24N).
pub fn acdc_bytes_single(n: usize) -> f64 {
    24.0 * n as f64
}

/// Bytes for the multipass implementation: every pass loads and stores
/// the full activation (4 passes ≈ 4× the fused traffic, §5.2).
pub fn acdc_bytes_multipass(n: usize, batch: usize, passes: usize) -> f64 {
    passes as f64 * acdc_bytes_batched(n, batch)
}

/// Arithmetic intensity of a batched ACDC layer: (4 + 5·log2 N)/8.
pub fn acdc_arithmetic_intensity(n: usize) -> f64 {
    let nf = n as f64;
    (4.0 + 5.0 * nf.log2()) / 8.0
}

/// FLOPs of a dense [n,n] layer on a batch: 2·N²·B.
pub fn dense_flops(n: usize, batch: usize) -> f64 {
    2.0 * (n as f64) * (n as f64) * batch as f64
}

/// Bytes of a dense layer on a batch: weights (4N², amortizable only if
/// cached) + activations in/out.
pub fn dense_bytes(n: usize, batch: usize) -> f64 {
    4.0 * (n as f64) * (n as f64) + 8.0 * (n * batch) as f64
}

/// Predicted fused-ACDC vs dense speedup on `hw` at (n, batch).
pub fn predicted_speedup(hw: &Hardware, n: usize, batch: usize) -> f64 {
    let acdc = hw.predict_seconds(acdc_flops(n, batch), acdc_bytes_batched(n, batch));
    let dense = hw.predict_seconds(dense_flops(n, batch), dense_bytes(n, batch));
    dense / acdc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn titan_x_balance_about_20() {
        let b = Hardware::TITAN_X.balance();
        assert!((19.0..21.0).contains(&b), "balance={b}");
    }

    #[test]
    fn ai_range_matches_paper() {
        // §5: "For the values of N we are interested in (128 → 16,384)
        // this arithmetic intensity varies between 4.9 and 9.3".
        let lo = acdc_arithmetic_intensity(128);
        let hi = acdc_arithmetic_intensity(16_384);
        assert!((lo - 4.875).abs() < 0.05, "lo={lo}");
        assert!((hi - 9.25).abs() < 0.1, "hi={hi}");
    }

    #[test]
    fn acdc_memory_bound_on_titan_x() {
        // AI < balance(≈20) for all paper sizes → memory-bound.
        for n in [128usize, 1024, 16_384] {
            assert!(acdc_arithmetic_intensity(n) < Hardware::TITAN_X.balance());
        }
    }

    #[test]
    fn dense_compute_bound_at_scale() {
        // Dense GEMM at batch 128 is FLOP-bound on the Titan X.
        let hw = Hardware::TITAN_X;
        let n = 4096;
        let flops_t = dense_flops(n, 128) / hw.peak_flops;
        let bytes_t = dense_bytes(n, 128) / hw.peak_bw;
        assert!(flops_t > bytes_t);
    }

    #[test]
    fn speedup_grows_with_n_and_reaches_10x() {
        // Paper: "ACDC still would outperform them by up to 10 times".
        let hw = Hardware::TITAN_X;
        let s_small = predicted_speedup(&hw, 512, 128);
        let s_large = predicted_speedup(&hw, 16_384, 128);
        assert!(s_large > s_small, "{s_small} -> {s_large}");
        assert!(s_large >= 10.0, "s_large={s_large}");
    }

    #[test]
    fn single_example_bytes_24n() {
        assert_eq!(acdc_bytes_single(1024), 24.0 * 1024.0);
    }

    #[test]
    fn multipass_is_4x_fused() {
        let fused = acdc_bytes_batched(1024, 128);
        let multi = acdc_bytes_multipass(1024, 128, 4);
        assert_eq!(multi / fused, 4.0);
    }

    #[test]
    fn predict_seconds_takes_max_leg() {
        let hw = Hardware {
            name: "t",
            peak_flops: 100.0,
            peak_bw: 10.0,
        };
        // 100 flops = 1s compute; 100 bytes = 10s memory → memory wins.
        assert_eq!(hw.predict_seconds(100.0, 100.0), 10.0);
    }

    #[test]
    fn acdc_flops_formula() {
        let f = acdc_flops(256, 1);
        assert_eq!(f, 4.0 * 256.0 + 5.0 * 256.0 * 8.0);
    }

    #[test]
    fn host_measurement_is_positive() {
        let hw = Hardware::measure_host(1);
        assert!(hw.peak_bw > 1e8, "bw={}", hw.peak_bw); // >0.1 GB/s sanity
    }
}
