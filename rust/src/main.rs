//! `acdc` — launcher CLI for the ACDC reproduction.
//!
//! Subcommands (each maps to a DESIGN.md experiment or a serving/training
//! entry point):
//!
//! ```text
//! acdc info                         inspect artifacts + platform
//! acdc params                       Table-1 analytic parameter audit (E3)
//! acdc fig2   [--sizes ...]         Figure-2 runtime sweep (E1)
//! acdc fig3   [--steps N]           Figure-3 approximation grid (E2)
//! acdc table1 [--steps N]           Table-1 measured MiniCaffeNet leg (E3)
//! acdc train-cnn [--config f.toml]  E6 end-to-end CNN training
//! acdc serve  [--config f.toml]     serving demo over the coordinator (E7)
//! acdc gateway [--addr host:port]   HTTP serving gateway (E8)
//! acdc shard  [--config topo.toml]  cluster shard (a gateway serving its
//!                                   slice of the topology)
//! acdc router [--config topo.toml]  cluster router: ring placement,
//!                                   replication, health checks, hedging
//! acdc loadgen [--addr host:port]   closed/open-loop load generator (E8)
//! acdc tail   [--addr host:port]    follow a gateway's slow-request ring
//! ```

use acdc::config::{ClusterConfig, Config, GatewayConfig, ServeConfig, TrainConfig, TrainerConfig};
use acdc::data::regression::RegressionTask;
use acdc::data::synthimg::ImageCorpus;
use acdc::experiments::{fig2, fig3, table1, trainer_bench};
use acdc::gateway::http;
use acdc::gateway::loadgen::{ArrivalMode, LoadgenConfig};
use acdc::gateway::Gateway;
use acdc::metrics::Registry;
use acdc::registry::{ModelRegistry, SellModel};
use acdc::runtime::Engine;
use acdc::serve::{ServeParams, Server};
use acdc::trainer::{CnnTrainer, CnnVariant, JobSpec, StepDecay, TrainerPool};
use acdc::util::bench::Bench;
use acdc::util::cli::{flag, opt, Args, OptSpec};
use acdc::util::json::{obj, Json};
use std::io::BufReader;
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let sub = argv.get(1).map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = std::iter::once(format!("acdc {sub}"))
        .chain(argv.iter().skip(2).cloned())
        .collect();
    let code = match run(sub, &rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(sub: &str, rest: &[String]) -> Result<(), String> {
    match sub {
        "info" => cmd_info(rest),
        "params" => cmd_params(rest),
        "bench" => cmd_bench(rest),
        "fig2" => cmd_fig2(rest),
        "fig3" => cmd_fig3(rest),
        "table1" => cmd_table1(rest),
        "train" => cmd_train(rest),
        "train-cnn" => cmd_train_cnn(rest),
        "jobs" => cmd_jobs(rest),
        "bench-trainer" => cmd_bench_trainer(rest),
        "bench-families" => cmd_bench_families(rest),
        "serve" => cmd_serve(rest),
        "gateway" => cmd_gateway(rest),
        // A shard IS a gateway (registry + trainer + HTTP front-end);
        // the separate name exists so topologies read correctly and so
        // shard-specific defaults can diverge later without a rename.
        "shard" => cmd_gateway(rest),
        "router" => cmd_router(rest),
        "loadgen" => cmd_loadgen(rest),
        "registry" => cmd_registry(rest),
        "tail" => cmd_tail(rest),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n{HELP}")),
    }
}

const HELP: &str = "acdc — ACDC: A Structured Efficient Linear Layer (ICLR 2016) reproduction

subcommands:
  info        inspect artifacts + PJRT platform
  params      Table-1 analytic parameter audit
  bench       batched SoA engine vs per-row ACDC comparison (E9,
              writes BENCH_acdc_batch.json); --all adds the loopback
              gateway leg and writes the unified BENCH_e2e_infer.json (E12)
  bench-trainer  full-SGD-step throughput sweep (E11, writes
              BENCH_trainer_step.json)
  bench-families  params × final MSE × rows/s grid over every trainable
              SELL family at matched budgets (E13, writes BENCH_families.json)
  fig2        Figure-2 runtime sweep (dense vs fused vs batched vs multipass ACDC)
  fig3        Figure-3 operator-approximation grid
  table1      Table-1 measured MiniCaffeNet leg
  train       background training job: submit to a running gateway's
              trainer pool (POST /v1/models/{name}/train) and watch it,
              or --standalone to train + promote in-process
  train-cnn   end-to-end CNN training (E6)
  jobs        trainer-pool admin client: list | pause | resume | cancel |
              promote against a running gateway
  serve       serving demo over the dynamic-batching coordinator
  gateway     multi-model HTTP serving gateway (POST /v1/models/{name}/infer,
              GET /v1/models, /healthz, /metrics, hot-swap admin endpoints)
  shard       cluster shard: a gateway serving its slice of a topology
              (alias of `gateway`; use --addr-file for ephemeral ports)
  router      cluster router: consistent-hash placement + replication +
              health-checked retry/hedging across [cluster] shards, and
              the rolling swap (POST /v1/admin/cluster/models/{name}/load)
  loadgen     closed/open-loop load generator against a running gateway
              (--targets a,b,c spreads workers across a cluster)
  registry    admin client: list | load | unload | alias | default against a
              running gateway's model registry
  tail        follow a running gateway's slow-request ring (GET /v1/debug/slow)
              and print one stage-attributed line per captured request
run `acdc <subcommand> --help` for options";

fn common_opts() -> Vec<acdc::util::cli::OptSpec> {
    vec![opt("artifacts", "artifacts directory", Some("artifacts"))]
}

fn cmd_info(rest: &[String]) -> Result<(), String> {
    let args = Args::parse_from(rest, common_opts())?;
    let engine = Engine::open(Path::new(args.get("artifacts").unwrap()))?;
    println!("platform: {}", engine.platform());
    let m = engine.manifest();
    println!("artifacts ({}):", m.artifacts.len());
    for a in &m.artifacts {
        let ins: Vec<String> = a
            .inputs
            .iter()
            .map(|s| format!("{}{:?}", s.name, s.shape))
            .collect();
        println!(
            "  {:<28} [{}] {}",
            a.name,
            a.tag_str("experiment").unwrap_or("-"),
            ins.join(" ")
        );
    }
    Ok(())
}

fn cmd_params(rest: &[String]) -> Result<(), String> {
    let _ = Args::parse_from(rest, vec![])?;
    print!("{}", table1::render_analytic());
    print!("{}", table1::render_fig4(None));
    Ok(())
}

fn cmd_bench(rest: &[String]) -> Result<(), String> {
    let opts = vec![
        opt("sizes", "layer sizes to sweep", Some("256,1024")),
        opt("batches", "batch sizes to sweep", Some("64,256")),
        opt("out", "JSON report path", Some("BENCH_acdc_batch.json")),
        opt(
            "e2e-out",
            "unified report path (--all)",
            Some("BENCH_e2e_infer.json"),
        ),
        opt("e2e-duration-s", "gateway loopback leg length (--all)", Some("3")),
        flag("fast", "shrink measurement windows for smoke runs"),
        flag(
            "all",
            "also run the loopback gateway leg and write the unified \
             BENCH_e2e_infer.json (engine GB/s + gateway p50/p95/p99)",
        ),
    ];
    let args = Args::parse_from(rest, opts)?;
    let sizes = args.get_usize_list("sizes")?.unwrap();
    let batches = args.get_usize_list("batches")?.unwrap();
    let bench = if args.flag("fast") {
        Bench::quick()
    } else {
        Bench::default()
    };
    let cases: Vec<(usize, usize)> = sizes
        .iter()
        .flat_map(|&n| batches.iter().map(move |&b| (n, b)))
        .collect();
    let rows = acdc::experiments::engine_bench::run(&cases, &bench);
    print!("{}", acdc::experiments::engine_bench::render(&rows));
    let out = args.get("out").unwrap();
    acdc::experiments::engine_bench::write_json(
        Path::new(out),
        &rows,
        "acdc bench (local cargo run)",
    )?;
    println!("wrote {out}");
    if args.flag("all") {
        use acdc::experiments::e2e_bench;
        let mut spec = e2e_bench::LoopbackSpec {
            duration: Duration::from_secs(args.get_usize("e2e-duration-s")?.unwrap() as u64),
            ..Default::default()
        };
        if args.flag("fast") {
            spec.duration = Duration::from_millis(500);
        }
        println!(
            "loopback gateway leg: native ACDC-{} (N={}), {} closed-loop workers, {:?}…",
            spec.depth, spec.n, spec.concurrency, spec.duration
        );
        let report = e2e_bench::gateway_loopback(&spec)?;
        print!("{}", report.render());
        let e2e_out = args.get("e2e-out").unwrap();
        e2e_bench::write_json(
            Path::new(e2e_out),
            &rows,
            Some(&report),
            &spec,
            "acdc bench --all (local cargo run)",
        )?;
        println!("wrote {e2e_out}");
    }
    match acdc::experiments::engine_bench::check_acceptance(&rows) {
        Ok(()) => {
            println!("acceptance: OK — serial batched engine ≥ 1.2x per-row at N=1024, batch=256");
            Ok(())
        }
        // The target shape wasn't in the sweep: report, don't fail.
        Err(e) if e.contains("no N=1024") => {
            println!("acceptance: not applicable — {e}");
            Ok(())
        }
        // The target shape was measured and missed the gate: nonzero exit.
        Err(e) => Err(format!("acceptance FAILED — {e}")),
    }
}

fn cmd_fig2(rest: &[String]) -> Result<(), String> {
    let mut opts = common_opts();
    opts.push(opt("sizes", "layer sizes to sweep", Some("128,256,512,1024,2048,4096")));
    opts.push(opt("batch", "batch size (paper: 128)", Some("128")));
    opts.push(flag("no-pjrt", "skip the PJRT-executed leg"));
    let args = Args::parse_from(rest, opts)?;
    let sizes = args.get_usize_list("sizes")?.unwrap();
    let batch = args.get_usize("batch")?.unwrap();
    let engine = if args.flag("no-pjrt") {
        None
    } else {
        Engine::open(Path::new(args.get("artifacts").unwrap())).ok()
    };
    let rows = fig2::run(&sizes, batch, &Bench::default(), engine.as_ref());
    print!("{}", fig2::render(&rows));
    match fig2::check_paper_shape(&rows) {
        Ok(()) => println!("paper-shape checks: OK"),
        Err(e) => println!("paper-shape checks: FAILED — {e}"),
    }
    Ok(())
}

fn cmd_fig3(rest: &[String]) -> Result<(), String> {
    let mut opts = common_opts();
    opts.push(opt("steps", "SGD steps per curve", Some("400")));
    opts.push(opt("ks", "cascade depths", Some("1,2,4,8,16,32")));
    opts.push(opt("rows", "regression rows", Some("10000")));
    opts.push(opt("seed", "rng seed", Some("0")));
    let args = Args::parse_from(rest, opts)?;
    let engine = Engine::open(Path::new(args.get("artifacts").unwrap()))?;
    let task = RegressionTask::generate(
        args.get_usize("rows")?.unwrap(),
        32,
        1e-4,
        args.get_usize("seed")?.unwrap() as u64,
    );
    let cells = fig3::run(
        &engine,
        &task,
        &args.get_usize_list("ks")?.unwrap(),
        args.get_usize("steps")?.unwrap(),
        args.get_usize("seed")?.unwrap() as u64,
    )?;
    print!("{}", fig3::render(&cells, &task));
    match fig3::check_paper_shape(&cells) {
        Ok(()) => println!("paper-shape checks: OK"),
        Err(e) => println!("paper-shape checks: FAILED — {e}"),
    }
    Ok(())
}

fn cmd_table1(rest: &[String]) -> Result<(), String> {
    let mut opts = common_opts();
    opts.push(opt("steps", "training steps per variant", Some("400")));
    opts.push(opt("train-rows", "train corpus size", Some("2000")));
    opts.push(opt("test-rows", "test corpus size", Some("1024")));
    opts.push(opt("seed", "rng seed", Some("0")));
    let args = Args::parse_from(rest, opts)?;
    print!("{}", table1::render_analytic());
    let engine = Engine::open(Path::new(args.get("artifacts").unwrap()))?;
    let rows = table1::run_measured(
        &engine,
        args.get_usize("train-rows")?.unwrap(),
        args.get_usize("test-rows")?.unwrap(),
        args.get_usize("steps")?.unwrap(),
        args.get_usize("seed")?.unwrap() as u64,
    )?;
    print!("{}", table1::render_measured(&rows));
    print!("{}", table1::render_fig4(Some(&rows)));
    table1::check_audit_consistency(&rows)?;
    match table1::check_paper_shape(&rows) {
        Ok(()) => println!("paper-shape checks: OK"),
        Err(e) => println!("paper-shape checks: FAILED — {e}"),
    }
    Ok(())
}

/// Knob options shared by `acdc train`'s HTTP and standalone modes.
/// Defaults mirror `TrainerConfig::default()` (the `[trainer]` section).
fn train_opts() -> Vec<OptSpec> {
    vec![
        opt("addr", "gateway address (HTTP mode)", Some("127.0.0.1:7878")),
        opt("model", "registry model the job trains toward", Some("trained")),
        opt("steps", "SGD step budget", Some("2000")),
        opt("batch", "minibatch rows", Some("64")),
        opt("lr", "base learning rate", Some("0.0002")),
        opt("momentum", "momentum coefficient", Some("0.9")),
        opt("lr-decay", "lr multiplier per decay (1.0 = constant)", Some("1.0")),
        opt("lr-decay-every", "steps between decays (0 = never)", Some("0")),
        opt("kind", "model family: acdc | fastfood | lowrank | circulant", Some("acdc")),
        opt("width", "width N (power of two for transform families)", Some("32")),
        opt("depth", "cascade depth K (acdc/circulant)", Some("2")),
        opt("rank", "low-rank factorization rank (0 = width/2)", Some("0")),
        opt("init-mean", "diagonal init mean (paper: 1.0)", Some("1.0")),
        opt("init-sigma", "diagonal init noise sigma", Some("0.1")),
        opt("rows", "regression dataset rows", Some("4096")),
        opt("noise", "dataset target-noise variance", Some("0.0001")),
        opt("seed", "rng seed (dataset + init)", Some("0")),
        opt("checkpoint-every", "checkpoint cadence in steps (0 = off)", Some("500")),
        opt("checkpoint-dir", "checkpoint directory (standalone mode)", Some("ckpts")),
        opt("target-ratio", "converged when loss <= first x this", Some("0.1")),
        flag("nonlinear", "train a ReLU+permutation cascade (§6.2 style)"),
        flag("no-promote", "do not auto-promote into the registry on completion"),
        flag("standalone", "train in-process instead of driving a gateway"),
        flag("no-watch", "submit and exit without polling progress"),
        opt("config", "TOML config ([serve] template, standalone mode)", None),
    ]
}

fn trainer_config_from_args(args: &Args) -> Result<TrainerConfig, String> {
    let tc = TrainerConfig {
        steps: args.get_usize("steps")?.unwrap(),
        batch: args.get_usize("batch")?.unwrap(),
        lr: args.get_f64("lr")?.unwrap(),
        momentum: args.get_f64("momentum")?.unwrap(),
        lr_decay: args.get_f64("lr-decay")?.unwrap(),
        lr_decay_every: args.get_usize("lr-decay-every")?.unwrap(),
        model_kind: args.get("kind").unwrap().to_string(),
        width: args.get_usize("width")?.unwrap(),
        depth: args.get_usize("depth")?.unwrap(),
        rank: args.get_usize("rank")?.unwrap(),
        init_mean: args.get_f64("init-mean")?.unwrap(),
        init_sigma: args.get_f64("init-sigma")?.unwrap(),
        nonlinear: args.flag("nonlinear"),
        dataset_rows: args.get_usize("rows")?.unwrap(),
        dataset_noise: args.get_f64("noise")?.unwrap(),
        seed: args.get_usize("seed")?.unwrap() as u64,
        checkpoint_every: args.get_usize("checkpoint-every")?.unwrap(),
        checkpoint_dir: args.get("checkpoint-dir").unwrap().to_string(),
        target_ratio: args.get_f64("target-ratio")?.unwrap(),
        promote_on_complete: !args.flag("no-promote"),
        max_jobs: TrainerConfig::default().max_jobs,
    };
    tc.validate()?;
    Ok(tc)
}

/// Render one job-status line (shared by the watch loops and `acdc jobs`).
fn job_line(j: &Json) -> String {
    let id = j.get("id").and_then(|x| x.as_i64()).unwrap_or(0);
    let model = j.get("model").and_then(|x| x.as_str()).unwrap_or("?");
    let state = j.get("state").and_then(|x| x.as_str()).unwrap_or("?");
    let step = j.get("step").and_then(|x| x.as_i64()).unwrap_or(0);
    let steps = j.get("steps").and_then(|x| x.as_i64()).unwrap_or(0);
    let loss = j.get("loss").and_then(|x| x.as_f64());
    let lr = j.get("lr").and_then(|x| x.as_f64()).unwrap_or(0.0);
    let promotions = j.get("promotions").and_then(|x| x.as_i64()).unwrap_or(0);
    let version = j.get("promoted_version").and_then(|x| x.as_i64());
    format!(
        "job {id}  {model:<16} {state:<10} step {step:>7}/{steps}  loss {}  lr {lr:.2e}  promotions {promotions}{}",
        loss.map_or("-".to_string(), |l| format!("{l:.4e}")),
        version.map_or(String::new(), |v| format!(" (v{v} live)")),
    )
}

fn promote_mode(tc: &TrainerConfig) -> &'static str {
    if tc.promote_on_complete {
        "auto"
    } else {
        "manual"
    }
}

fn cmd_train(rest: &[String]) -> Result<(), String> {
    let args = Args::parse_from(rest, train_opts())?;
    let tc = trainer_config_from_args(&args)?;
    let model = args.get("model").unwrap().to_string();
    if args.flag("standalone") {
        return train_standalone(&args, &tc, &model);
    }
    let addr = args.get("addr").unwrap().to_string();
    let body = obj(vec![
        ("model_kind", Json::Str(tc.model_kind.clone())),
        ("steps", Json::Num(tc.steps as f64)),
        ("batch", Json::Num(tc.batch as f64)),
        ("lr", Json::Num(tc.lr)),
        ("momentum", Json::Num(tc.momentum)),
        ("lr_decay", Json::Num(tc.lr_decay)),
        ("lr_decay_every", Json::Num(tc.lr_decay_every as f64)),
        ("width", Json::Num(tc.width as f64)),
        ("depth", Json::Num(tc.depth as f64)),
        ("rank", Json::Num(tc.rank as f64)),
        ("init_mean", Json::Num(tc.init_mean)),
        ("init_sigma", Json::Num(tc.init_sigma)),
        ("nonlinear", Json::Bool(tc.nonlinear)),
        ("rows", Json::Num(tc.dataset_rows as f64)),
        ("noise", Json::Num(tc.dataset_noise)),
        ("seed", Json::Num(tc.seed as f64)),
        ("checkpoint_every", Json::Num(tc.checkpoint_every as f64)),
        ("target_ratio", Json::Num(tc.target_ratio)),
        ("promote", Json::Str(promote_mode(tc).to_string())),
    ]);
    let v = admin_call(&addr, "POST", &format!("/v1/models/{model}/train"), Some(body))?;
    let id = v
        .get("job")
        .and_then(|x| x.as_i64())
        .ok_or("gateway answered without a job id")?;
    println!("job {id} training model '{model}' ({} steps)", tc.steps);
    if args.flag("no-watch") {
        println!("watch with: acdc jobs list --addr {addr}");
        return Ok(());
    }
    loop {
        std::thread::sleep(Duration::from_millis(500));
        let v = admin_call(&addr, "GET", "/v1/jobs", None)?;
        let jobs = v
            .get("jobs")
            .and_then(|j| j.as_arr())
            .ok_or("malformed jobs listing")?;
        let Some(job) = jobs
            .iter()
            .find(|j| j.get("id").and_then(|x| x.as_i64()) == Some(id))
        else {
            return Err(format!("job {id} disappeared from the listing"));
        };
        println!("{}", job_line(job));
        let state = job.get("state").and_then(|x| x.as_str()).unwrap_or("?");
        if matches!(state, "completed" | "cancelled" | "failed") {
            if state == "failed" {
                let err = job.get("error").and_then(|x| x.as_str()).unwrap_or("?");
                return Err(format!("job {id} failed: {err}"));
            }
            return Ok(());
        }
    }
}

fn train_standalone(args: &Args, tc: &TrainerConfig, model: &str) -> Result<(), String> {
    let template = match args.get("config") {
        Some(path) => ServeConfig::from_config(&Config::from_file(Path::new(path))?)?,
        None => ServeConfig::default(),
    };
    let metrics = Arc::new(Registry::new());
    let registry = Arc::new(ModelRegistry::new(template, Arc::clone(&metrics)));
    let pool = TrainerPool::new(Arc::clone(&registry), metrics, tc.clone());
    let spec = JobSpec::from_config(tc);
    println!(
        "standalone: training '{model}' — {} N={} K={} batch={} lr={} ({} steps max)",
        tc.model_kind, tc.width, tc.depth, tc.batch, tc.lr, tc.steps
    );
    let id = pool.submit(model, spec).map_err(|e| e.to_string())?;
    let t0 = Instant::now();
    let status = loop {
        match pool.join(id, Duration::from_millis(500)) {
            Some(status) => break status,
            None => {
                let s = pool.status(id).map_err(|e| e.to_string())?;
                println!(
                    "step {:>7}/{}  loss {:.4e}  lr {:.2e}",
                    s.step, s.steps, s.loss, s.lr
                );
            }
        }
    };
    println!(
        "job {id} {} after {:.1}s: loss {:.4e} (first {:.4e}, {:.1}x drop)",
        status.state.as_str(),
        t0.elapsed().as_secs_f64(),
        status.loss,
        status.first_loss,
        status.first_loss / status.loss.max(f64::MIN_POSITIVE),
    );
    if let Some(path) = &status.last_checkpoint {
        println!("checkpoint: {path}");
    }
    if let Some(v) = status.promoted_version {
        let handle = registry.resolve(model).map_err(|e| e.to_string())?;
        println!("promoted: registry serves '{model}' v{} (width {})", v, handle.width());
    }
    if let Some(err) = &status.error {
        return Err(format!("job failed: {err}"));
    }
    pool.shutdown();
    Ok(())
}

fn cmd_jobs(rest: &[String]) -> Result<(), String> {
    const USAGE: &str = "usage: acdc jobs <list | pause | resume | cancel | promote> [options]
  list                 show every training job on the gateway
  pause   --id N       freeze job N at its next step boundary
  resume  --id N       resume a paused job
  cancel  --id N       cancel a running or paused job
  promote --id N       checkpoint + hot-swap job N's parameters now";
    let opts = vec![
        opt("addr", "gateway address", Some("127.0.0.1:7878")),
        opt("id", "job id (from `acdc jobs list`)", None),
    ];
    let args = Args::parse_from(rest, opts)?;
    let addr = args.get("addr").unwrap().to_string();
    let action = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| USAGE.to_string())?;
    match action {
        "list" => {
            let v = admin_call(&addr, "GET", "/v1/jobs", None)?;
            let jobs = v
                .get("jobs")
                .and_then(|j| j.as_arr())
                .ok_or("malformed jobs listing")?;
            println!("{} job(s):", jobs.len());
            for j in jobs {
                println!("  {}", job_line(j));
            }
            Ok(())
        }
        "pause" | "resume" | "cancel" | "promote" => {
            let id = args
                .get_usize("id")?
                .ok_or_else(|| format!("--id is required for '{action}'\n{USAGE}"))?;
            let v = admin_call(&addr, "POST", &format!("/v1/jobs/{id}/{action}"), None)?;
            match v.get("status") {
                Some(status) if status.get("id").is_some() => {
                    println!("{action}: {}", job_line(status))
                }
                _ => println!("{action}: ok"),
            }
            Ok(())
        }
        other => Err(format!("unknown jobs action '{other}'\n{USAGE}")),
    }
}

fn cmd_bench_trainer(rest: &[String]) -> Result<(), String> {
    let opts = vec![
        opt("sizes", "layer widths to sweep", Some("64,256,1024")),
        opt("batch", "minibatch rows per step", Some("64")),
        opt("depth", "cascade depth", Some("2")),
        opt("out", "JSON report path", Some("BENCH_trainer_step.json")),
        flag("fast", "shrink measurement windows for smoke runs"),
    ];
    let args = Args::parse_from(rest, opts)?;
    let sizes = args.get_usize_list("sizes")?.unwrap();
    let batch = args.get_usize("batch")?.unwrap();
    let depth = args.get_usize("depth")?.unwrap();
    let bench = if args.flag("fast") {
        Bench::quick()
    } else {
        Bench::default()
    };
    let cases: Vec<(usize, usize, usize)> = sizes.iter().map(|&n| (n, batch, depth)).collect();
    let rows = trainer_bench::run(&cases, &bench);
    print!("{}", trainer_bench::render(&rows));
    let out = args.get("out").unwrap();
    trainer_bench::write_json(Path::new(out), &rows, "acdc bench-trainer (local cargo run)")?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_bench_families(rest: &[String]) -> Result<(), String> {
    let opts = vec![
        opt("n", "operator width (FamilyTuning is validated at 16)", Some("16")),
        opt("steps", "per-family step override (0 = family budgets)", Some("0")),
        opt("out", "JSON report path", Some("BENCH_families.json")),
        flag("fast", "shrink measurement windows for smoke runs"),
    ];
    let args = Args::parse_from(rest, opts)?;
    let n = args.get_usize("n")?.unwrap();
    let steps = match args.get_usize("steps")?.unwrap() {
        0 => None,
        s => Some(s),
    };
    let bench = if args.flag("fast") {
        Bench::quick()
    } else {
        Bench::default()
    };
    let rows = acdc::experiments::families_bench::run(n, steps, &bench);
    print!("{}", acdc::experiments::families_bench::render(&rows));
    let out = args.get("out").unwrap();
    acdc::experiments::families_bench::write_json(
        Path::new(out),
        &rows,
        "acdc bench-families (local cargo run)",
    )?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_train_cnn(rest: &[String]) -> Result<(), String> {
    let mut opts = common_opts();
    opts.push(opt("config", "TOML config file", None));
    opts.push(opt("steps", "SGD steps", Some("400")));
    opts.push(opt("variant", "acdc | dense", Some("acdc")));
    let args = Args::parse_from(rest, opts)?;
    let tc = match args.get("config") {
        Some(path) => TrainConfig::from_config(&Config::from_file(Path::new(path))?)?,
        None => TrainConfig {
            artifacts_dir: args.get("artifacts").unwrap().to_string(),
            steps: args.get_usize("steps")?.unwrap(),
            ..Default::default()
        },
    };
    let variant = match args.get("variant").unwrap() {
        "acdc" => CnnVariant::Acdc,
        "dense" => CnnVariant::Dense,
        v => return Err(format!("unknown variant '{v}'")),
    };
    let engine = Engine::open(Path::new(&tc.artifacts_dir))?;
    let train = ImageCorpus::generate(2000, 0.15, tc.seed);
    let test = ImageCorpus::generate(1024, 0.15, tc.seed + 1);
    let mut t = CnnTrainer::new(&engine, variant, tc.seed)?;
    println!("training {variant:?} MiniCaffeNet: {} steps, lr {}", tc.steps, tc.lr);
    let schedule = StepDecay::new(tc.lr, tc.lr_decay, tc.lr_decay_every);
    let (curve, eval) = t.run(&train, &test, tc.steps, &schedule, tc.eval_every)?;
    println!("{}", curve.render(2));
    println!(
        "test: loss {:.3}, accuracy {:.1}%",
        eval.loss,
        eval.accuracy * 100.0
    );
    if let Some(path) = &tc.checkpoint_path {
        t.checkpoint().save(Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<(), String> {
    let mut opts = common_opts();
    opts.push(opt("config", "TOML config file", None));
    opts.push(opt("requests", "demo request count", Some("500")));
    opts.push(flag("native", "use the pure-rust executor instead of PJRT"));
    let args = Args::parse_from(rest, opts)?;
    let sc = match args.get("config") {
        Some(path) => ServeConfig::from_config(&Config::from_file(Path::new(path))?)?,
        None => ServeConfig {
            artifacts_dir: args.get("artifacts").unwrap().to_string(),
            ..Default::default()
        },
    };
    let n = 256;
    let server = if args.flag("native") {
        let mut rng = acdc::util::rng::Pcg32::seeded(1);
        Server::start_native(
            &sc,
            acdc::sell::acdc::AcdcCascade::nonlinear(
                n,
                12,
                acdc::sell::init::DiagInit::CAFFENET,
                &mut rng,
            ),
        )
    } else {
        Server::start_pjrt(&sc, ServeParams::random(n, 12, 10, 1), n)?
    };
    let requests = args.get_usize("requests")?.unwrap();
    println!("serving demo: {requests} requests (buckets {:?})", sc.buckets);
    let mut rng = acdc::util::rng::Pcg32::seeded(7);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|_| server.submit(rng.normal_vec(n, 0.0, 1.0)).expect("submit"))
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(120))
            .map_err(|e| e.to_string())?
            .output?;
    }
    println!(
        "done: {:.0} req/s\n{}",
        requests as f64 / t0.elapsed().as_secs_f64(),
        server.metrics_report()
    );
    server.shutdown();
    Ok(())
}

fn cmd_gateway(rest: &[String]) -> Result<(), String> {
    let mut opts = common_opts();
    opts.push(opt("config", "TOML config file ([gateway]/[registry] sections)", None));
    opts.push(opt("addr", "listen address (overrides config)", None));
    opts.push(opt(
        "addr-file",
        "write the bound address to this file (ephemeral-port discovery)",
        None,
    ));
    opts.push(opt("n", "demo model width", Some("256")));
    opts.push(opt("k", "demo cascade depth", Some("12")));
    opts.push(opt("demo-model", "name the demo model registers under", Some("demo")));
    opts.push(opt("duration-s", "serve N seconds then drain (0 = forever)", Some("0")));
    opts.push(flag("native", "use the pure-rust executor instead of PJRT"));
    opts.push(flag("no-demo", "start with only [registry] preloads, no demo model"));
    let args = Args::parse_from(rest, opts)?;
    let mut sc = match args.get("config") {
        Some(path) => ServeConfig::from_config(&Config::from_file(Path::new(path))?)?,
        None => ServeConfig {
            artifacts_dir: args.get("artifacts").unwrap().to_string(),
            ..Default::default()
        },
    };
    if let Some(addr) = args.get("addr") {
        sc.gateway.addr = addr.to_string();
    }
    let n = args.get_usize("n")?.unwrap();
    let k = args.get_usize("k")?.unwrap();
    let metrics = Arc::new(acdc::metrics::Registry::new());
    let registry = Arc::new(ModelRegistry::new(sc.clone(), Arc::clone(&metrics)));
    if !args.flag("no-demo") {
        let demo = args.get("demo-model").unwrap();
        if args.flag("native") {
            let mut rng = acdc::util::rng::Pcg32::seeded(1);
            let cascade = acdc::sell::acdc::AcdcCascade::nonlinear(
                n,
                k,
                acdc::sell::init::DiagInit::CAFFENET,
                &mut rng,
            );
            registry
                .load(demo, SellModel::Acdc(cascade), None)
                .map_err(|e| e.to_string())?;
        } else {
            // Shares the gateway's metrics registry so the coordinator and
            // worker series stay visible on GET /metrics.
            let server = Server::start_pjrt_with_metrics(
                &sc,
                ServeParams::random(n, k, 10, 1),
                n,
                Arc::clone(&metrics),
            )?;
            registry
                .insert_server(demo, "pjrt", server, None)
                .map_err(|e| e.to_string())?;
        }
    }
    for (name, path) in &sc.registry.preload {
        let v = registry
            .load_path(name, Path::new(path), None)
            .map_err(|e| format!("preload {name}={path}: {e}"))?;
        println!("preloaded model '{name}' v{v} from {path}");
    }
    if !sc.registry.default_model.is_empty() {
        registry
            .set_default(&sc.registry.default_model)
            .map_err(|e| e.to_string())?;
    }
    if registry.is_empty() {
        return Err("no models: pass a [registry] preload list or drop --no-demo".into());
    }
    // The training-job pool shares the registry + metrics, so promoted
    // checkpoints hot-swap live models and trainer.* series land on
    // GET /metrics.
    let trainer = Arc::new(TrainerPool::new(
        Arc::clone(&registry),
        Arc::clone(&metrics),
        sc.trainer.clone(),
    ));
    let gateway = Gateway::start_registry_with_trainer(registry, trainer, sc.gateway.clone())?;
    write_addr_file(&args, gateway.local_addr())?;
    println!("gateway listening on http://{}", gateway.local_addr());
    println!("  POST /v1/models/{{name}}/infer  {{\"features\": [...]}} or {{\"rows\": [[...], ...]}}");
    println!("  POST /v1/infer                 same, against the default model");
    println!("  GET  /v1/models                registry listing");
    println!("  POST /v1/admin/models/{{name}}/load|unload   hot-swap admin");
    println!("  POST /v1/models/{{name}}/train  background training job ([trainer] knobs)");
    println!("  GET  /v1/jobs                  job listing; POST /v1/jobs/{{id}}/pause|resume|cancel|promote");
    println!("  GET  /healthz /metrics         liveness, Prometheus text");
    let duration_s = args.get_usize("duration-s")?.unwrap();
    if duration_s == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration_s as u64));
    println!("draining...");
    gateway.shutdown();
    println!("gateway stopped");
    Ok(())
}

/// Write the bound address to `--addr-file` if the flag was given —
/// multi-process tests spawn shards/routers on port 0 and read the file
/// to discover where each child actually landed.
fn write_addr_file(args: &Args, addr: std::net::SocketAddr) -> Result<(), String> {
    if let Some(path) = args.get("addr-file") {
        std::fs::write(path, format!("{addr}\n")).map_err(|e| format!("write {path}: {e}"))?;
    }
    Ok(())
}

fn cmd_router(rest: &[String]) -> Result<(), String> {
    let opts = vec![
        opt(
            "config",
            "TOML topology file ([cluster] + [gateway] sections)",
            None,
        ),
        opt("addr", "listen address (overrides config)", None),
        opt(
            "addr-file",
            "write the bound address to this file (ephemeral-port discovery)",
            None,
        ),
        opt("duration-s", "serve N seconds then drain (0 = forever)", Some("0")),
    ];
    let args = Args::parse_from(rest, opts)?;
    let Some(path) = args.get("config") else {
        return Err("router requires --config with a [cluster] shard topology".into());
    };
    let cfg = Config::from_file(Path::new(path))?;
    let cluster = ClusterConfig::from_config(&cfg)?;
    let mut gw = GatewayConfig::from_config(&cfg)?;
    if let Some(addr) = args.get("addr") {
        gw.addr = addr.to_string();
    }
    let shard_count = cluster.shards.len();
    let replication = cluster.replication;
    let gateway = Gateway::start_router(cluster, gw)?;
    write_addr_file(&args, gateway.local_addr())?;
    println!(
        "router listening on http://{}  ({shard_count} shards, R={replication})",
        gateway.local_addr()
    );
    println!("  POST /v1/infer | /v1/models/{{name}}/infer   proxied across the ring");
    println!("  POST /v1/admin/cluster/models/{{name}}/load  rolling version swap");
    println!("  GET  /v1/cluster                            topology + shard health");
    println!("  GET  /healthz /metrics                      liveness, Prometheus text");
    let duration_s = args.get_usize("duration-s")?.unwrap();
    if duration_s == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration_s as u64));
    println!("draining...");
    gateway.shutdown();
    println!("router stopped");
    Ok(())
}

fn cmd_loadgen(rest: &[String]) -> Result<(), String> {
    let opts = vec![
        opt("addr", "gateway address", Some("127.0.0.1:7878")),
        opt("mode", "arrival process: closed | open", Some("closed")),
        opt("rps", "aggregate request rate for open mode", Some("1000")),
        opt("concurrency", "worker connections", Some("8")),
        opt("duration-s", "run length in seconds", Some("5")),
        opt("width", "model width N (features per row)", Some("256")),
        opt("rows", "rows-per-request mix, e.g. 1,1,8", Some("1")),
        opt("timeout-ms", "per-request timeout", Some("5000")),
        opt(
            "deadline-ms",
            "per-request deadline budget sent as x-acdc-deadline-ms (off by default)",
            None,
        ),
        opt("seed", "rng seed", Some("0")),
        opt(
            "targets",
            "comma-separated addresses to spread workers across (cluster runs)",
            None,
        ),
        flag("binary", "send the binary f32 wire frame instead of JSON"),
    ];
    let args = Args::parse_from(rest, opts)?;
    let mode = match args.get("mode").unwrap() {
        "closed" => ArrivalMode::Closed,
        "open" => ArrivalMode::Open {
            rps: args.get_f64("rps")?.unwrap(),
        },
        other => return Err(format!("unknown mode '{other}' (closed | open)")),
    };
    let cfg = LoadgenConfig {
        addr: args.get("addr").unwrap().to_string(),
        mode,
        concurrency: args.get_usize("concurrency")?.unwrap(),
        duration: Duration::from_secs(args.get_usize("duration-s")?.unwrap() as u64),
        width: args.get_usize("width")?.unwrap(),
        rows_mix: args.get_usize_list("rows")?.unwrap(),
        timeout: Duration::from_millis(args.get_usize("timeout-ms")?.unwrap() as u64),
        deadline_ms: args.get_usize("deadline-ms")?.map(|ms| ms as u64),
        seed: args.get_usize("seed")?.unwrap() as u64,
        targets: args
            .get("targets")
            .map(|s| s.split(',').map(|t| t.trim().to_string()).collect())
            .unwrap_or_default(),
        binary: args.flag("binary"),
    };
    let against = if cfg.targets.is_empty() {
        cfg.addr.clone()
    } else {
        cfg.targets.join(",")
    };
    println!(
        "loadgen: {:?} × {} workers for {:?} against {} ({})",
        cfg.mode,
        cfg.concurrency,
        cfg.duration,
        against,
        if cfg.binary { "binary frame" } else { "json" },
    );
    let report = acdc::gateway::loadgen::run(&cfg)?;
    print!("{}", report.render());
    println!("{}", report.to_json().to_pretty());
    Ok(())
}

/// Render one slow-ring entry (from `GET /v1/debug/slow`) as a single
/// human-readable line: trace id, total latency, status, shape, and the
/// per-stage µs breakdown with the slowest stage called out.
fn slow_line(e: &Json) -> String {
    let trace = e.get("trace_id").and_then(|x| x.as_str()).unwrap_or("?");
    let total_us = e.get("total_us").and_then(|x| x.as_i64()).unwrap_or(0);
    let status = e.get("status").and_then(|x| x.as_i64()).unwrap_or(0);
    let rows = e.get("rows").and_then(|x| x.as_i64()).unwrap_or(0);
    let batch = e.get("batch_size").and_then(|x| x.as_i64()).unwrap_or(0);
    let slowest = e.get("slowest").and_then(|x| x.as_str()).unwrap_or("?");
    let stages = e
        .get("stages")
        .and_then(|s| s.as_obj())
        .map(|o| {
            // Alphabetical key order from the JSON object is fine here: the
            // slowest stage is already called out by name.
            o.iter()
                .map(|(k, v)| {
                    let us = v.as_i64().unwrap_or(0);
                    format!("{}={}µs", k.trim_end_matches("_us"), us)
                })
                .collect::<Vec<_>>()
                .join(" ")
        })
        .unwrap_or_default();
    format!(
        "trace {trace}  {:.1}ms  status {status}  rows {rows}  batch {batch}  slowest {slowest}  [{stages}]",
        total_us as f64 / 1000.0,
    )
}

fn cmd_tail(rest: &[String]) -> Result<(), String> {
    let opts = vec![
        opt("addr", "gateway address", Some("127.0.0.1:7878")),
        opt("interval-ms", "poll interval", Some("1000")),
        flag("once", "print the current ring contents and exit"),
    ];
    let args = Args::parse_from(rest, opts)?;
    let addr = args.get("addr").unwrap().to_string();
    let interval = Duration::from_millis(args.get_usize("interval-ms")?.unwrap() as u64);
    let mut seen: std::collections::HashSet<String> = std::collections::HashSet::new();
    let mut first = true;
    loop {
        let v = admin_call(&addr, "GET", "/v1/debug/slow", None)?;
        if first {
            let threshold_us = v.get("threshold_us").and_then(|x| x.as_i64()).unwrap_or(0);
            let capacity = v.get("capacity").and_then(|x| x.as_i64()).unwrap_or(0);
            println!(
                "tailing http://{addr}/v1/debug/slow (threshold {:.0}ms, ring capacity {capacity})",
                threshold_us as f64 / 1000.0,
            );
            first = false;
        }
        let entries = v
            .get("entries")
            .and_then(|x| x.as_arr())
            .ok_or("malformed /v1/debug/slow response")?;
        // The ring reports newest-first; print oldest-first so the terminal
        // reads top-to-bottom in arrival order, and dedupe across polls.
        for e in entries.iter().rev() {
            let trace = e.get("trace_id").and_then(|x| x.as_str()).unwrap_or("?");
            if seen.insert(trace.to_string()) {
                println!("{}", slow_line(e));
            }
        }
        if args.flag("once") {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// One admin HTTP exchange against a running gateway.
fn admin_call(addr: &str, method: &str, path: &str, body: Option<Json>) -> Result<Json, String> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
    let payload = body.map(|b| b.to_string().into_bytes()).unwrap_or_default();
    http::write_request(
        &mut stream,
        method,
        path,
        &[("content-type", "application/json")],
        &payload,
    )
    .map_err(|e| format!("write: {e}"))?;
    let resp = http::read_response(&mut reader).map_err(|e| format!("read: {e}"))?;
    let parsed = Json::parse(resp.body_str())
        .map_err(|e| format!("unparseable response ({}): {e}", resp.status))?;
    if resp.status != 200 {
        let msg = parsed
            .get("error")
            .and_then(|e| e.as_str())
            .unwrap_or("(no error body)");
        return Err(format!("gateway answered {}: {msg}", resp.status));
    }
    Ok(parsed)
}

fn cmd_registry(rest: &[String]) -> Result<(), String> {
    const USAGE: &str = "usage: acdc registry <list | load | unload | alias | default> [options]
  list                                  show loaded models
  load    --model m --path ckpt.bin     load/hot-swap a checkpoint [--version N]
  unload  --model m                     remove a model (409 while busy)
  alias   --name stable --target m      point an alias at a model
  default --model m                     route legacy /v1/infer to m";
    let opts = vec![
        opt("addr", "gateway address", Some("127.0.0.1:7878")),
        opt("model", "model name", None),
        opt("path", "checkpoint manifest path (load)", None),
        opt("version", "explicit version number (load)", None),
        opt("name", "alias name (alias)", None),
        opt("target", "alias target model (alias)", None),
    ];
    let args = Args::parse_from(rest, opts)?;
    let addr = args.get("addr").unwrap().to_string();
    let action = args
        .positional()
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| USAGE.to_string())?;
    let need = |key: &str| -> Result<String, String> {
        args.get(key)
            .map(String::from)
            .ok_or_else(|| format!("--{key} is required for '{action}'\n{USAGE}"))
    };
    match action {
        "list" => {
            let v = admin_call(&addr, "GET", "/v1/models", None)?;
            let models = v
                .get("models")
                .and_then(|m| m.as_arr())
                .ok_or("malformed listing")?;
            println!("{} model(s):", models.len());
            for m in models {
                let name = m.get("name").and_then(|x| x.as_str()).unwrap_or("?");
                let version = m.get("version").and_then(|x| x.as_i64()).unwrap_or(0);
                let kind = m.get("kind").and_then(|x| x.as_str()).unwrap_or("?");
                let width = m.get("width").and_then(|x| x.as_i64()).unwrap_or(0);
                let inflight = m.get("inflight").and_then(|x| x.as_i64()).unwrap_or(0);
                let is_default = m.get("default").and_then(|x| x.as_bool()).unwrap_or(false);
                let aliases: Vec<&str> = m
                    .get("aliases")
                    .and_then(|a| a.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_str()).collect())
                    .unwrap_or_default();
                println!(
                    "  {name:<20} v{version:<4} {kind:<9} n={width:<6} inflight={inflight}{}{}",
                    if aliases.is_empty() {
                        String::new()
                    } else {
                        format!("  aliases={}", aliases.join(","))
                    },
                    if is_default { "  [default]" } else { "" },
                );
            }
            Ok(())
        }
        "load" => {
            let model = need("model")?;
            let path = need("path")?;
            let mut pairs = vec![("path", Json::Str(path))];
            if let Some(v) = args.get_usize("version")? {
                pairs.push(("version", Json::Num(v as f64)));
            }
            let v = admin_call(
                &addr,
                "POST",
                &format!("/v1/admin/models/{model}/load"),
                Some(obj(pairs)),
            )?;
            println!(
                "loaded '{model}' as v{}",
                v.get("version").and_then(|x| x.as_i64()).unwrap_or(0)
            );
            Ok(())
        }
        "unload" => {
            let model = need("model")?;
            admin_call(
                &addr,
                "POST",
                &format!("/v1/admin/models/{model}/unload"),
                None,
            )?;
            println!("unloaded '{model}'");
            Ok(())
        }
        "alias" => {
            let name = need("name")?;
            let target = need("target")?;
            admin_call(
                &addr,
                "POST",
                &format!("/v1/admin/aliases/{name}"),
                Some(obj(vec![("target", Json::Str(target.clone()))])),
            )?;
            println!("alias '{name}' → '{target}'");
            Ok(())
        }
        "default" => {
            let model = need("model")?;
            admin_call(
                &addr,
                "POST",
                "/v1/admin/default",
                Some(obj(vec![("model", Json::Str(model.clone()))])),
            )?;
            println!("default model set to '{model}'");
            Ok(())
        }
        other => Err(format!("unknown registry action '{other}'\n{USAGE}")),
    }
}
