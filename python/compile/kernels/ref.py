"""Pure-jnp correctness oracle for the ACDC kernel.

This module is the ground truth the Pallas kernel (``acdc.py``) is tested
against. Everything here follows the paper exactly:

* eq. (9): orthonormal DCT-II matrix ``C`` with ``C^{-1} = C^T``
* §4:     ``ACDC(x) = x · A · C · D · C^{-1}`` with ``A = diag(a)``,
          ``D = diag(d)``; optionally a bias is added after ``D`` (the paper
          places biases on ``D`` only, §6.2)
* §6.2:   deep cascades interleave ReLU non-linearities and fixed
          permutations so adjacent SELLs are incoherent.

The convention is row-vector based like the paper: ``x`` has shape
``[batch, n]`` and matrices multiply on the right.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=32)
def _dct_matrix_np(n: int) -> np.ndarray:
    """Orthonormal DCT-II matrix per paper eq. (9), as float64 numpy.

    ``y = x @ dct_matrix(n)`` computes the DCT-II of each row of ``x``.
    Entry ``c[j, k] = sqrt(2/n) * eps_k * cos(pi * (2j + 1) * k / (2n))``
    with ``eps_0 = 1/sqrt(2)`` and ``eps_k = 1`` otherwise, which makes the
    matrix orthogonal: ``C @ C.T == I``.
    """
    j = np.arange(n)[:, None].astype(np.float64)  # spatial index (rows)
    k = np.arange(n)[None, :].astype(np.float64)  # frequency index (cols)
    c = np.sqrt(2.0 / n) * np.cos(np.pi * (2.0 * j + 1.0) * k / (2.0 * n))
    c[:, 0] *= 1.0 / np.sqrt(2.0)
    return c


def dct_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthonormal DCT-II matrix (eq. 9) with ``C^{-1} = C^T``."""
    return jnp.asarray(_dct_matrix_np(n), dtype=dtype)


def dct(x: jnp.ndarray) -> jnp.ndarray:
    """DCT-II of each row of ``x`` (orthonormal)."""
    return x @ dct_matrix(x.shape[-1], x.dtype)


def idct(x: jnp.ndarray) -> jnp.ndarray:
    """Inverse DCT (DCT-III, orthonormal) of each row of ``x``."""
    return x @ dct_matrix(x.shape[-1], x.dtype).T


def acdc(
    x: jnp.ndarray,
    a: jnp.ndarray,
    d: jnp.ndarray,
    bias: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """One ACDC layer: ``y = ((x ⊙ a) C ⊙ d + bias) C^T``.

    Args:
      x:    ``[batch, n]`` input rows.
      a:    ``[n]`` signal-domain diagonal.
      d:    ``[n]`` spectral-domain diagonal.
      bias: optional ``[n]`` bias added after ``D`` (paper §6.2).
    """
    n = x.shape[-1]
    c = dct_matrix(n, x.dtype)
    h1 = x * a
    h2 = h1 @ c
    h3 = h2 * d
    if bias is not None:
        h3 = h3 + bias
    return h3 @ c.T


def acdc_dense_equivalent(
    a: jnp.ndarray, d: jnp.ndarray, bias: jnp.ndarray | None = None
):
    """Materialize the dense ``(W, b)`` a single ACDC layer represents.

    ``acdc(x, a, d, bias) == x @ W + b`` — used by tests and by the
    operator-approximation experiment to compare against ``W_true``.
    """
    n = a.shape[-1]
    c = dct_matrix(n, a.dtype)
    w = (jnp.diag(a) @ c) @ jnp.diag(d) @ c.T
    b = jnp.zeros((n,), a.dtype) if bias is None else bias @ c.T
    return w, b


def acdc_cascade(
    x: jnp.ndarray,
    a_stack: jnp.ndarray,
    d_stack: jnp.ndarray,
    bias_stack: jnp.ndarray | None = None,
    perms: jnp.ndarray | None = None,
    relu: bool = False,
) -> jnp.ndarray:
    """Order-K ACDC cascade (Definition 1), optionally with ReLU + perms.

    Args:
      x:          ``[batch, n]``.
      a_stack:    ``[K, n]`` diagonals for A_1..A_K.
      d_stack:    ``[K, n]`` diagonals for D_1..D_K.
      bias_stack: optional ``[K, n]`` biases on D.
      perms:      optional ``[K, n]`` int32 permutations applied *after*
                  each layer (paper §6.2: adjacent SELLs made incoherent).
      relu:       interleave ReLU after every layer except the last.
    """
    k = a_stack.shape[0]
    h = x
    for i in range(k):
        b = None if bias_stack is None else bias_stack[i]
        h = acdc(h, a_stack[i], d_stack[i], b)
        if perms is not None:
            h = h[..., perms[i]]
        if relu and i != k - 1:
            h = jnp.maximum(h, 0.0)
    return h


def cascade_dense_equivalent(
    a_stack: jnp.ndarray, d_stack: jnp.ndarray
) -> jnp.ndarray:
    """Dense matrix equal to a (linear, no-ReLU, no-perm) ACDC cascade."""
    n = a_stack.shape[-1]
    w = jnp.eye(n, dtype=a_stack.dtype)
    for i in range(a_stack.shape[0]):
        wi, _ = acdc_dense_equivalent(a_stack[i], d_stack[i])
        w = w @ wi
    return w
