"""Layer-1 Pallas kernels for the ACDC structured efficient linear layer.

The paper's §5 GPU implementation fuses the whole ``A → DCT → D → IDCT``
chain into a single kernel so each element makes exactly one round trip to
main memory (8N bytes/row). The TPU/Pallas rethink (DESIGN.md
§Hardware-Adaptation):

* the fused chain lives in one ``pallas_call`` — intermediates ``h1..h3``
  stay in VMEM (the TPU analogue of the paper's "temporary low-level
  memory");
* the DCT is expressed as a matmul against the precomputed orthonormal
  DCT-II matrix so it runs on the MXU systolic array. On TPU a matmul-DCT
  beats a butterfly for the layer sizes the paper studies because the MXU
  executes dense ``[b, n] @ [n, n]`` at near-peak throughput while a
  butterfly is VPU-bound and strided;
* the batch dimension is tiled over the Pallas grid via ``BlockSpec`` — the
  analogue of the paper's per-threadblock batching.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so kernels are lowered to plain HLO. Structure (blocking, VMEM
residency) is still exactly what a real TPU lowering would use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows per grid step. 128 matches the paper's benchmark batch size and the
# MXU/VPU lane width; callers with smaller batches get a single-step grid.
DEFAULT_BLOCK_B = 128


def _block_b(batch: int, block_b: int | None) -> int:
    b = block_b or DEFAULT_BLOCK_B
    if batch % b != 0:
        # Fall back to the largest divisor of batch that is <= b. Pallas
        # requires the grid to tile the batch exactly; serving-side bucketing
        # (rust coordinator) keeps batches at power-of-two sizes, so this
        # path only triggers in tests with odd shapes.
        b = next(d for d in range(min(b, batch), 0, -1) if batch % d == 0)
    return b


def _acdc_kernel(x_ref, a_ref, d_ref, b_ref, c_ref, ct_ref, o_ref):
    """Fused single-call ACDC: ``o = ((x ⊙ a) C ⊙ d + bias) C^T``.

    All refs are VMEM-resident blocks. ``c_ref``/``ct_ref`` hold the DCT-II
    matrix and its transpose; they are broadcast to every grid step and the
    compiler keeps them resident (the paper's "perfect caching of A and D").
    """
    h1 = x_ref[...] * a_ref[...]
    # MXU: DCT as matmul. float32 accumulation regardless of input dtype.
    h2 = jnp.dot(h1, c_ref[...], preferred_element_type=jnp.float32)
    h3 = h2 * d_ref[...] + b_ref[...]
    o_ref[...] = jnp.dot(h3, ct_ref[...], preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def acdc(
    x: jnp.ndarray,
    a: jnp.ndarray,
    d: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    *,
    block_b: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """One fused ACDC layer (paper §5.1 "single call implementation").

    Args:
      x:    ``[batch, n]`` activations.
      a:    ``[n]`` signal-domain diagonal of ``A``.
      d:    ``[n]`` spectral-domain diagonal of ``D``.
      bias: optional ``[n]`` bias applied after ``D`` (paper §6.2).
      block_b: rows per grid step (defaults to 128).
      interpret: keep True on CPU; False only for real TPU lowering.
    """
    batch, n = x.shape
    bb = _block_b(batch, block_b)
    c = ref.dct_matrix(n, x.dtype)
    b = jnp.zeros((n,), x.dtype) if bias is None else bias
    grid = (batch // bb,)
    return pl.pallas_call(
        _acdc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),  # x: tile batch
            pl.BlockSpec((n,), lambda i: (0,)),  # a: resident
            pl.BlockSpec((n,), lambda i: (0,)),  # d: resident
            pl.BlockSpec((n,), lambda i: (0,)),  # bias: resident
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # C: resident
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # C^T: resident
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        interpret=interpret,
    )(x, a, d, b, c, c.T)


def _cascade_kernel(
    x_ref, a_ref, d_ref, b_ref, p_ref, c_ref, ct_ref, o_ref, *, k: int, relu: bool
):
    """Fused order-K cascade: K ACDC layers + perms + ReLU in one kernel.

    ``a_ref``/``d_ref``/``b_ref`` are ``[K, n]`` stacks, ``p_ref`` is a
    ``[K, n]`` int32 permutation bank. The whole chain runs out of VMEM —
    one HBM load of ``x`` and one store of ``o`` per row, the deep-cascade
    generalization of the paper's 8N-bytes/row ideal.
    """
    h = x_ref[...]
    for i in range(k):  # K is static — unrolled at trace time
        h1 = h * a_ref[i, :]
        h2 = jnp.dot(h1, c_ref[...], preferred_element_type=jnp.float32)
        h3 = h2 * d_ref[i, :] + b_ref[i, :]
        h = jnp.dot(h3, ct_ref[...], preferred_element_type=jnp.float32)
        h = jnp.take(h, p_ref[i, :], axis=1)
        if relu and i != k - 1:
            h = jnp.maximum(h, 0.0)
    o_ref[...] = h.astype(o_ref.dtype)


def acdc_cascade(
    x: jnp.ndarray,
    a_stack: jnp.ndarray,
    d_stack: jnp.ndarray,
    bias_stack: jnp.ndarray | None = None,
    perms: jnp.ndarray | None = None,
    relu: bool = False,
    *,
    block_b: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused order-K ACDC cascade (Definition 1 + §6.2 interleaving).

    Args mirror :func:`ref.acdc_cascade`; ``perms=None`` uses identity
    permutations so the kernel stays a single code path.
    """
    batch, n = x.shape
    k = int(a_stack.shape[0])
    bb = _block_b(batch, block_b)
    c = ref.dct_matrix(n, x.dtype)
    b_stack = (
        jnp.zeros((k, n), x.dtype) if bias_stack is None else bias_stack
    )
    if perms is None:
        perms = jnp.tile(jnp.arange(n, dtype=jnp.int32)[None, :], (k, 1))
    grid = (batch // bb,)
    kernel = functools.partial(_cascade_kernel, k=k, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        interpret=interpret,
    )(x, a_stack, d_stack, b_stack, perms, c, c.T)


def vmem_bytes(n: int, k: int = 1, block_b: int = DEFAULT_BLOCK_B) -> int:
    """Estimated VMEM footprint (bytes, f32) of the fused cascade kernel.

    Used by DESIGN/EXPERIMENTS to check the block fits the ~16 MiB/core VMEM
    budget of a real TPU: two ``[block_b, n]`` live activation tiles, the
    ``[n, n]`` DCT matrix and its transpose, and the ``[K, n]`` A/D/bias/perm
    banks.
    """
    act = 2 * block_b * n * 4
    dct_mats = 2 * n * n * 4
    banks = 4 * k * n * 4
    return act + dct_mats + banks
