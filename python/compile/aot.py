"""AOT pipeline: lower every L2 entry point to HLO text + manifest.json.

This is the *only* place python touches the artifact directory. Each entry
point is jitted, lowered to StableHLO, converted to an XlaComputation and
dumped as **HLO text** — not a serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

``manifest.json`` records, for every artifact, the positional input/output
names, shapes and dtypes so the rust ``runtime::registry`` can feed and
decode executables without any knowledge of jax. Outputs are always a
single tuple (``return_tuple=True``).

Usage:  python -m compile.aot --outdir ../artifacts [--only PREFIX]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import acdc as kernels

PERM_SEED = 7  # fixed permutation bank seed, shared with tests

# Figure-3 workload shapes (paper §6.1): W_true is 32×32, X is 10000×32;
# we lower one minibatch-step per cascade depth K.
FIG3_N = 32
FIG3_BATCH = 250
FIG3_KS = [1, 2, 4, 8, 16, 32]

# Serving batch buckets for the coordinator's size-bucketed batcher.
SERVE_BUCKETS = [1, 8, 32, 128]

# Single-layer forward sizes for the runtime micro-bench (§Perf, E1 PJRT leg).
FWD_SIZES = [256, 512, 1024, 2048]

CNN_TRAIN_BATCH = 64
CNN_EVAL_BATCH = 256


def _dtype_str(dt) -> str:
    return {
        np.dtype("float32"): "f32",
        np.dtype("int32"): "i32",
        np.dtype("uint32"): "u32",
    }[np.dtype(dt)]


class Spec(NamedTuple):
    name: str
    shape: tuple
    dtype: str

    def to_json(self):
        return {"name": self.name, "shape": list(self.shape), "dtype": self.dtype}


def _specs(names, examples) -> list[Spec]:
    flat, _ = jax.tree_util.tree_flatten(examples)
    assert len(names) == len(flat), (names, [f.shape for f in flat])
    return [
        Spec(n, tuple(f.shape), _dtype_str(f.dtype)) for n, f in zip(names, flat)
    ]


def to_hlo_text(fn: Callable, *example_args) -> str:
    """Lower ``fn`` at the example shapes and render HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default text dump
    # elides big constants as `constant({...})`, and the rust side's HLO
    # text parser (xla_extension 0.5.1) silently parses that as ZEROS —
    # the baked DCT matrices would vanish.
    return comp.as_hlo_text(print_large_constants=True)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


class Artifact(NamedTuple):
    name: str
    fn: Callable
    example_args: tuple
    input_names: list
    output_names: list
    tags: dict


def _named_tuple_names(cls, prefix: str) -> list:
    return [f"{prefix}{f}" for f in cls._fields]


def build_registry() -> list[Artifact]:
    arts: list[Artifact] = []
    perms_cnn = model.make_perms(PERM_SEED, model.CNN_K, model.N_FEAT)

    # -- quickstart: one fused ACDC layer ---------------------------------
    def quickstart(x, a, d, bias):
        return kernels.acdc(x, a, d, bias)

    arts.append(
        Artifact(
            "quickstart_acdc_b4_n64",
            quickstart,
            (_f32(4, 64), _f32(64), _f32(64), _f32(64)),
            ["x", "a", "d", "bias"],
            ["y"],
            {"experiment": "quickstart", "n": 64, "batch": 4},
        )
    )

    # -- single-layer forwards for the perf harness -----------------------
    for n in FWD_SIZES:
        arts.append(
            Artifact(
                f"acdc_fwd_b128_n{n}",
                quickstart,
                (_f32(128, n), _f32(n), _f32(n), _f32(n)),
                ["x", "a", "d", "bias"],
                ["y"],
                {"experiment": "fig2_pjrt", "n": n, "batch": 128},
            )
        )

    # -- serving cascade (classifier head) per batch bucket ---------------
    for b in SERVE_BUCKETS:
        def serve(a_stack, d_stack, bias_stack, cls_w, cls_b, feat,
                  _perms=perms_cnn):
            return model.serve_classifier(
                a_stack, d_stack, bias_stack, cls_w, cls_b, feat, _perms
            )

        arts.append(
            Artifact(
                f"serve_cascade_b{b}_n{model.N_FEAT}_k{model.CNN_K}",
                serve,
                (
                    _f32(model.CNN_K, model.N_FEAT),
                    _f32(model.CNN_K, model.N_FEAT),
                    _f32(model.CNN_K, model.N_FEAT),
                    _f32(model.N_FEAT, model.N_CLASSES),
                    _f32(model.N_CLASSES),
                    _f32(b, model.N_FEAT),
                ),
                ["a_stack", "d_stack", "bias_stack", "cls_w", "cls_b", "feat"],
                ["log_probs"],
                {
                    "experiment": "serve",
                    "batch": b,
                    "n": model.N_FEAT,
                    "k": model.CNN_K,
                    "perm_seed": PERM_SEED,
                },
            )
        )

    # -- Figure 3: ACDC_K regression steps + dense baseline ---------------
    for k in FIG3_KS:
        arts.append(
            Artifact(
                f"fig3_step_k{k}",
                model.fig3_step,
                (
                    _f32(k, FIG3_N),
                    _f32(k, FIG3_N),
                    _f32(FIG3_BATCH, FIG3_N),
                    _f32(FIG3_BATCH, FIG3_N),
                    _f32(),
                ),
                ["a_stack", "d_stack", "x", "y", "lr"],
                ["a_stack", "d_stack", "loss"],
                {"experiment": "fig3", "k": k, "n": FIG3_N, "batch": FIG3_BATCH},
            )
        )
    arts.append(
        Artifact(
            "fig3_dense_step",
            model.dense_step,
            (_f32(FIG3_N, FIG3_N), _f32(FIG3_BATCH, FIG3_N),
             _f32(FIG3_BATCH, FIG3_N), _f32()),
            ["w", "x", "y", "lr"],
            ["w", "loss"],
            {"experiment": "fig3", "k": 0, "n": FIG3_N, "batch": FIG3_BATCH},
        )
    )

    # -- MiniCaffeNet (Table-1 analogue + E6 end-to-end) -------------------
    acdc_param_specs = (
        _f32(5, 5, 1, 8), _f32(8), _f32(3, 3, 8, 16), _f32(16),
        _f32(model.CNN_K, model.N_FEAT), _f32(model.CNN_K, model.N_FEAT),
        _f32(model.CNN_K, model.N_FEAT),
        _f32(model.N_FEAT, model.N_CLASSES), _f32(model.N_CLASSES),
    )
    dense_param_specs = (
        _f32(5, 5, 1, 8), _f32(8), _f32(3, 3, 8, 16), _f32(16),
        _f32(model.N_FEAT, model.N_FEAT), _f32(model.N_FEAT),
        _f32(model.N_FEAT, model.N_FEAT), _f32(model.N_FEAT),
        _f32(model.N_FEAT, model.N_CLASSES), _f32(model.N_CLASSES),
    )
    acdc_names = list(model.CnnAcdcParams._fields)
    dense_names = list(model.CnnDenseParams._fields)

    def acdc_step(*flat):
        np_, nm = len(acdc_names), len(acdc_names)
        params = model.CnnAcdcParams(*flat[:np_])
        moms = model.CnnAcdcParams(*flat[np_:np_ + nm])
        images, labels, lr, seed = flat[np_ + nm:]
        p2, m2, loss = model.cnn_acdc_train_step(
            params, moms, images, labels, lr, seed, perms_cnn
        )
        return (*p2, *m2, loss)

    arts.append(
        Artifact(
            "cnn_acdc_train_step",
            acdc_step,
            (*acdc_param_specs, *acdc_param_specs,
             _f32(CNN_TRAIN_BATCH, model.IMG, model.IMG, 1),
             _i32(CNN_TRAIN_BATCH), _f32(), _u32()),
            [*acdc_names, *[f"m_{n}" for n in acdc_names],
             "images", "labels", "lr", "seed"],
            [*acdc_names, *[f"m_{n}" for n in acdc_names], "loss"],
            {"experiment": "table1", "variant": "acdc", "k": model.CNN_K,
             "n": model.N_FEAT, "batch": CNN_TRAIN_BATCH,
             "perm_seed": PERM_SEED},
        )
    )

    def acdc_eval(*flat):
        params = model.CnnAcdcParams(*flat[:len(acdc_names)])
        images, labels = flat[len(acdc_names):]
        return model.cnn_acdc_eval(params, images, labels, perms_cnn)

    arts.append(
        Artifact(
            "cnn_acdc_eval",
            acdc_eval,
            (*acdc_param_specs,
             _f32(CNN_EVAL_BATCH, model.IMG, model.IMG, 1),
             _i32(CNN_EVAL_BATCH)),
            [*acdc_names, "images", "labels"],
            ["loss", "correct"],
            {"experiment": "table1", "variant": "acdc",
             "batch": CNN_EVAL_BATCH, "perm_seed": PERM_SEED},
        )
    )

    def dense_step(*flat):
        np_ = len(dense_names)
        params = model.CnnDenseParams(*flat[:np_])
        moms = model.CnnDenseParams(*flat[np_:2 * np_])
        images, labels, lr = flat[2 * np_:]
        p2, m2, loss = model.cnn_dense_train_step(params, moms, images, labels, lr)
        return (*p2, *m2, loss)

    arts.append(
        Artifact(
            "cnn_dense_train_step",
            dense_step,
            (*dense_param_specs, *dense_param_specs,
             _f32(CNN_TRAIN_BATCH, model.IMG, model.IMG, 1),
             _i32(CNN_TRAIN_BATCH), _f32()),
            [*dense_names, *[f"m_{n}" for n in dense_names],
             "images", "labels", "lr"],
            [*dense_names, *[f"m_{n}" for n in dense_names], "loss"],
            {"experiment": "table1", "variant": "dense",
             "n": model.N_FEAT, "batch": CNN_TRAIN_BATCH},
        )
    )

    def dense_eval(*flat):
        params = model.CnnDenseParams(*flat[:len(dense_names)])
        images, labels = flat[len(dense_names):]
        return model.cnn_dense_eval(params, images, labels)

    arts.append(
        Artifact(
            "cnn_dense_eval",
            dense_eval,
            (*dense_param_specs,
             _f32(CNN_EVAL_BATCH, model.IMG, model.IMG, 1),
             _i32(CNN_EVAL_BATCH)),
            [*dense_names, "images", "labels"],
            ["loss", "correct"],
            {"experiment": "table1", "variant": "dense",
             "batch": CNN_EVAL_BATCH},
        )
    )

    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="only artifacts with this prefix")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"format": 1, "perm_seed": PERM_SEED, "artifacts": []}
    for art in build_registry():
        if args.only and not art.name.startswith(args.only):
            continue
        text = to_hlo_text(art.fn, *art.example_args)
        fname = f"{art.name}.hlo.txt"
        path = os.path.join(args.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(art.fn, *art.example_args)
        manifest["artifacts"].append(
            {
                "name": art.name,
                "file": fname,
                "inputs": [s.to_json() for s in _specs(art.input_names, art.example_args)],
                "outputs": [s.to_json() for s in _specs(art.output_names, out_tree)],
                "tags": art.tags,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"lowered {art.name}: {len(text)} chars -> {path}")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts -> {mpath}")


if __name__ == "__main__":
    main()
