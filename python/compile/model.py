"""Layer-2 JAX models: the paper's compute graphs, built on the L1 kernel.

Three workloads, matching DESIGN.md §3:

* **Figure 3** — linear-operator approximation: an order-K linear ACDC
  cascade trained by SGD to recover a dense 32×32 ``W_true`` (paper eq. 15).
* **Table 1 / Figure 4 / E6** — "MiniCaffeNet": a small convnet whose FC
  block is either two dense layers (reference) or a stack of ACDC layers
  interleaved with ReLU and fixed permutations (paper §6.2), with all the
  §6.2 riders: bias on D only, no weight decay on A/D, per-matrix LR
  multipliers, conv-feature scaling, dropout before the last 5 SELLs.
* **Serving** — the ACDC classifier forward pass at several batch sizes for
  the rust coordinator's size-bucketed batcher.

The ACDC layer uses ``jax.custom_vjp`` with the paper's §4 closed-form
gradients (eqs. 10–14); the backward pass *recomputes* ``h2`` instead of
storing it, mirroring the paper's §5 memory-saving choice.

Everything here is lowered once by ``aot.py``; nothing in this module runs
at serving/training time.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import acdc as kernels
from .kernels import ref


# ---------------------------------------------------------------------------
# ACDC layer with the paper's closed-form backward (§4, eqs. 10–14)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def acdc_layer(x, a, d, bias):
    """One ACDC layer ``y = ((x ⊙ a) C ⊙ d + bias) Cᵀ`` (fused L1 kernel)."""
    return kernels.acdc(x, a, d, bias)


def _acdc_layer_fwd(x, a, d, bias):
    # Residuals: inputs only. h2 is recomputed in the backward pass — the
    # paper §5: "it was decided instead to recompute these during the
    # backward pass, increasing runtime while saving memory".
    return kernels.acdc(x, a, d, bias), (x, a, d)


def _acdc_layer_bwd(res, g):
    x, a, d = res
    n = x.shape[-1]
    c = ref.dct_matrix(n, x.dtype)
    h1 = x * a
    h2 = h1 @ c  # recomputed
    # eq. (10): ∂L/∂d = h2 ⊙ (C ∂L/∂y)   (row-vector form: g @ C)
    gh3 = g @ c
    gd = jnp.sum(h2 * gh3, axis=0)
    # bias sits after D (§6.2), so its gradient is ∂L/∂h3 summed over batch.
    gbias = jnp.sum(gh3, axis=0)
    # eq. (12): ∂L/∂a = x ⊙ C⁻¹ d ⊙ (C ∂L/∂y)
    gh1 = (gh3 * d) @ c.T
    ga = jnp.sum(x * gh1, axis=0)
    # eq. (14): ∂L/∂x = a ⊙ C⁻¹ d ⊙ (C ∂L/∂y)
    gx = a * gh1
    return gx, ga, gd, gbias


acdc_layer.defvjp(_acdc_layer_fwd, _acdc_layer_bwd)


def acdc_cascade(x, a_stack, d_stack, bias_stack=None, perms=None, relu=False):
    """Order-K cascade of :func:`acdc_layer` (+ §6.2 perm/ReLU interleave).

    Differentiable through the custom VJP of each layer. ``perms`` is a
    ``[K, n]`` int array of fixed (non-learned) permutations.
    """
    k = a_stack.shape[0]
    n = x.shape[-1]
    h = x
    for i in range(k):
        b = jnp.zeros((n,), x.dtype) if bias_stack is None else bias_stack[i]
        h = acdc_layer(h, a_stack[i], d_stack[i], b)
        if perms is not None:
            h = jnp.take(h, perms[i], axis=1)
        if relu and i != k - 1:
            h = jnp.maximum(h, 0.0)
    return h


# ---------------------------------------------------------------------------
# Initialization (paper §6)
# ---------------------------------------------------------------------------


def init_diagonals(key, k: int, n: int, mean: float = 1.0, sigma: float = 0.1):
    """Diagonal init N(mean, sigma²) — paper's identity-plus-noise scheme.

    Figure 3 "good" init: mean=1, sigma=1e-1. Figure 3 "bad" (standard
    linear-layer style) init: mean=0, sigma=1e-3. §6.2 uses N(1, 0.061).
    """
    ka, kd = jax.random.split(key)
    a = mean + sigma * jax.random.normal(ka, (k, n), jnp.float32)
    d = mean + sigma * jax.random.normal(kd, (k, n), jnp.float32)
    return a, d


def make_perms(seed: int, k: int, n: int) -> np.ndarray:
    """Fixed permutation bank (one per layer) so adjacent SELLs are
    incoherent (§6.2). Deterministic in ``seed``; baked into the lowered
    HLO as constants."""
    rng = np.random.default_rng(seed)
    return np.stack([rng.permutation(n) for _ in range(k)]).astype(np.int32)


# ---------------------------------------------------------------------------
# Figure 3: linear-operator approximation (paper §6.1, eq. 15)
# ---------------------------------------------------------------------------


def fig3_predict(a_stack, d_stack, x):
    """Pure linear cascade (no ReLU/perm/bias) — the Fig. 3 model."""
    return acdc_cascade(x, a_stack, d_stack)


def fig3_loss(a_stack, d_stack, x, y):
    pred = fig3_predict(a_stack, d_stack, x)
    return jnp.mean(jnp.sum((pred - y) ** 2, axis=-1))


def fig3_step(a_stack, d_stack, x, y, lr):
    """One SGD step of the Fig. 3 regression. Returns (a', d', loss)."""
    loss, grads = jax.value_and_grad(fig3_loss, argnums=(0, 1))(
        a_stack, d_stack, x, y
    )
    ga, gd = grads
    return a_stack - lr * ga, d_stack - lr * gd, loss


def dense_step(w, x, y, lr):
    """Dense-matrix baseline for Fig. 3 (the paper's 'dense' curve)."""

    def loss_fn(w):
        return jnp.mean(jnp.sum((x @ w - y) ** 2, axis=-1))

    loss, gw = jax.value_and_grad(loss_fn)(w)
    return w - lr * gw, loss


# ---------------------------------------------------------------------------
# MiniCaffeNet (Table 1 analogue, DESIGN.md substitution S2)
# ---------------------------------------------------------------------------

IMG = 16  # input resolution (16×16 grayscale)
N_CLASSES = 10
N_FEAT = 256  # flattened conv features == SELL width (power of two)
CNN_K = 12  # paper §6.2: 12 stacked ACDC transforms
FEATURE_SCALE = 0.1  # §6.2: conv output scaled by 0.1
LR_MULT_A = 24.0  # §6.2 learning-rate multipliers
LR_MULT_D = 12.0
MOMENTUM = 0.65
WEIGHT_DECAY = 5e-4
DROPOUT_P = 0.1  # §6.2: dropout before each of the last 5 SELLs
DROPOUT_LAYERS = 5


class CnnAcdcParams(NamedTuple):
    """Learnable parameters of the ACDC-FC MiniCaffeNet, in lowering order."""

    conv1_w: jnp.ndarray  # [5,5,1,8]
    conv1_b: jnp.ndarray  # [8]
    conv2_w: jnp.ndarray  # [3,3,8,16]
    conv2_b: jnp.ndarray  # [16]
    a_stack: jnp.ndarray  # [K, 256]
    d_stack: jnp.ndarray  # [K, 256]
    bias_stack: jnp.ndarray  # [K, 256] (bias on D only, §6.2)
    cls_w: jnp.ndarray  # [256, 10]
    cls_b: jnp.ndarray  # [10]


class CnnDenseParams(NamedTuple):
    """Learnable parameters of the dense-FC reference MiniCaffeNet."""

    conv1_w: jnp.ndarray
    conv1_b: jnp.ndarray
    conv2_w: jnp.ndarray
    conv2_b: jnp.ndarray
    fc6_w: jnp.ndarray  # [256, 256]
    fc6_b: jnp.ndarray  # [256]
    fc7_w: jnp.ndarray  # [256, 256]
    fc7_b: jnp.ndarray  # [256]
    cls_w: jnp.ndarray
    cls_b: jnp.ndarray


def init_cnn_acdc(key) -> CnnAcdcParams:
    ks = jax.random.split(key, 6)
    he = jax.nn.initializers.he_normal()
    a, d = init_diagonals(ks[0], CNN_K, N_FEAT, mean=1.0, sigma=0.061)
    return CnnAcdcParams(
        conv1_w=he(ks[1], (5, 5, 1, 8), jnp.float32),
        conv1_b=jnp.zeros((8,), jnp.float32),
        conv2_w=he(ks[2], (3, 3, 8, 16), jnp.float32),
        conv2_b=jnp.zeros((16,), jnp.float32),
        a_stack=a,
        d_stack=d,
        bias_stack=jnp.zeros((CNN_K, N_FEAT), jnp.float32),
        cls_w=he(ks[3], (N_FEAT, N_CLASSES), jnp.float32),
        cls_b=jnp.zeros((N_CLASSES,), jnp.float32),
    )


def init_cnn_dense(key) -> CnnDenseParams:
    ks = jax.random.split(key, 6)
    he = jax.nn.initializers.he_normal()
    return CnnDenseParams(
        conv1_w=he(ks[1], (5, 5, 1, 8), jnp.float32),
        conv1_b=jnp.zeros((8,), jnp.float32),
        conv2_w=he(ks[2], (3, 3, 8, 16), jnp.float32),
        conv2_b=jnp.zeros((16,), jnp.float32),
        fc6_w=he(ks[0], (N_FEAT, N_FEAT), jnp.float32),
        fc6_b=jnp.zeros((N_FEAT,), jnp.float32),
        fc7_w=he(ks[4], (N_FEAT, N_FEAT), jnp.float32),
        fc7_b=jnp.zeros((N_FEAT,), jnp.float32),
        cls_w=he(ks[3], (N_FEAT, N_CLASSES), jnp.float32),
        cls_b=jnp.zeros((N_CLASSES,), jnp.float32),
    )


def _conv_features(params, images):
    """Shared conv trunk: 16×16×1 → 256 features (scaled by 0.1, §6.2)."""
    h = jax.lax.conv_general_dilated(
        images,
        params.conv1_w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + params.conv1_b
    h = jnp.maximum(h, 0.0)
    h = jax.lax.reduce_window(
        h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
    h = jax.lax.conv_general_dilated(
        h,
        params.conv2_w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + params.conv2_b
    h = jnp.maximum(h, 0.0)
    h = jax.lax.reduce_window(
        h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
    feat = h.reshape(h.shape[0], -1)  # [B, 256]
    return feat * FEATURE_SCALE


def _sell_block(params: CnnAcdcParams, feat, perms, dropout_key=None):
    """The §6.2 FC replacement: 12 ACDC layers + ReLU + perms (+ dropout).

    Dropout (p=0.1) is placed before each of the last ``DROPOUT_LAYERS``
    SELLs, exactly as in the paper. ``dropout_key=None`` disables dropout
    (eval / serving).
    """
    k = params.a_stack.shape[0]
    h = feat
    for i in range(k):
        if dropout_key is not None and i >= k - DROPOUT_LAYERS:
            mask_key = jax.random.fold_in(dropout_key, i)
            keep = jax.random.bernoulli(mask_key, 1.0 - DROPOUT_P, h.shape)
            h = jnp.where(keep, h / (1.0 - DROPOUT_P), 0.0)
        h = acdc_layer(h, params.a_stack[i], params.d_stack[i], params.bias_stack[i])
        h = jnp.take(h, perms[i], axis=1)
        h = jnp.maximum(h, 0.0)  # ReLU after every SELL (§6.2 interleave)
    return h


def cnn_acdc_logits(params: CnnAcdcParams, images, perms, dropout_key=None):
    feat = _conv_features(params, images)
    h = _sell_block(params, feat, perms, dropout_key)
    return h @ params.cls_w + params.cls_b


def cnn_dense_logits(params: CnnDenseParams, images):
    feat = _conv_features(params, images)
    h = jnp.maximum(feat @ params.fc6_w + params.fc6_b, 0.0)
    h = jnp.maximum(h @ params.fc7_w + params.fc7_b, 0.0)
    return h @ params.cls_w + params.cls_b


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


# --- SGD with the §6.2 riders -------------------------------------------------


def _acdc_lr_mults(params: CnnAcdcParams) -> CnnAcdcParams:
    ones = jax.tree_util.tree_map(lambda p: jnp.ones((), p.dtype), params)
    return ones._replace(
        a_stack=jnp.asarray(LR_MULT_A, jnp.float32),
        d_stack=jnp.asarray(LR_MULT_D, jnp.float32),
        bias_stack=jnp.asarray(LR_MULT_D, jnp.float32),
    )


def _acdc_wd_mask(params: CnnAcdcParams) -> CnnAcdcParams:
    """§6.2: no weight decay on A or D (or their biases)."""
    ones = jax.tree_util.tree_map(lambda p: jnp.ones((), p.dtype), params)
    zero = jnp.zeros((), jnp.float32)
    return ones._replace(a_stack=zero, d_stack=zero, bias_stack=zero)


def _sgd_update(params, moms, grads, lr, lr_mults, wd_mask):
    """SGD + momentum 0.65 + weight decay 5e-4 with per-leaf riders."""
    new_moms = jax.tree_util.tree_map(
        lambda p, m, g, wd: MOMENTUM * m + g + WEIGHT_DECAY * wd * p,
        params, moms, grads, wd_mask,
    )
    new_params = jax.tree_util.tree_map(
        lambda p, m, mult: p - lr * mult * m, params, new_moms, lr_mults
    )
    return new_params, new_moms


def cnn_acdc_train_step(params: CnnAcdcParams, moms: CnnAcdcParams, images,
                        labels, lr, seed, perms):
    """One SGD step of the ACDC MiniCaffeNet. Returns (params', moms', loss)."""

    def loss_fn(p):
        key = jax.random.PRNGKey(seed.astype(jnp.uint32))
        logits = cnn_acdc_logits(p, images, perms, dropout_key=key)
        return _xent(logits, labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params, new_moms = _sgd_update(
        params, moms, grads, lr, _acdc_lr_mults(params), _acdc_wd_mask(params)
    )
    return new_params, new_moms, loss


def cnn_dense_train_step(params: CnnDenseParams, moms: CnnDenseParams, images,
                         labels, lr):
    """One SGD step of the dense reference MiniCaffeNet."""

    def loss_fn(p):
        return _xent(cnn_dense_logits(p, images), labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    ones = jax.tree_util.tree_map(lambda p: jnp.ones((), p.dtype), params)
    new_params, new_moms = _sgd_update(params, moms, grads, lr, ones, ones)
    return new_params, new_moms, loss


def cnn_acdc_eval(params: CnnAcdcParams, images, labels, perms):
    """Eval step: (mean loss, #correct) over a batch; dropout off."""
    logits = cnn_acdc_logits(params, images, perms, dropout_key=None)
    loss = _xent(logits, labels)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
    return loss, correct


def cnn_dense_eval(params: CnnDenseParams, images, labels):
    logits = cnn_dense_logits(params, images)
    loss = _xent(logits, labels)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
    return loss, correct


# ---------------------------------------------------------------------------
# Serving forward (rust coordinator hot path)
# ---------------------------------------------------------------------------


def serve_classifier(a_stack, d_stack, bias_stack, cls_w, cls_b, feat, perms):
    """Classifier head over precomputed features: fused SELL stack + dense
    softmax layer. This is the executable the rust batcher dispatches to —
    one per batch bucket."""
    h = kernels.acdc_cascade(
        feat, a_stack, d_stack, bias_stack, jnp.asarray(perms), relu=True
    )
    logits = h @ cls_w + cls_b
    return jax.nn.log_softmax(logits, axis=-1)


def serve_acdc_forward(a_stack, d_stack, bias_stack, x, perms):
    """Raw fused cascade forward (quickstart / micro-bench artifact)."""
    return kernels.acdc_cascade(
        x, a_stack, d_stack, bias_stack, jnp.asarray(perms), relu=False
    )
