"""AOT pipeline tests: manifest consistency and HLO-text well-formedness.

These run the lowering in-process (no files needed) for a representative
subset, and validate the manifest writer's invariants the rust registry
relies on: positional specs match the lowered program, names are unique,
dtypes are in the supported set.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def registry():
    return aot.build_registry()


def test_registry_names_unique(registry):
    names = [a.name for a in registry]
    assert len(names) == len(set(names))


def test_registry_covers_all_experiments(registry):
    tags = {a.tags["experiment"] for a in registry}
    assert {"quickstart", "fig2_pjrt", "serve", "fig3", "table1"} <= tags


def test_fig3_ks_match_paper(registry):
    ks = sorted(
        a.tags["k"] for a in registry
        if a.tags["experiment"] == "fig3" and a.tags["k"] > 0
    )
    assert ks == [1, 2, 4, 8, 16, 32]


def test_serve_buckets_powers_of_two(registry):
    bs = sorted(a.tags["batch"] for a in registry if a.tags["experiment"] == "serve")
    assert bs == [1, 8, 32, 128]
    assert all(b & (b - 1) == 0 for b in bs)


def test_input_names_match_example_arg_count(registry):
    for art in registry:
        flat, _ = jax.tree_util.tree_flatten(art.example_args)
        assert len(flat) == len(art.input_names), art.name


def test_output_names_match_eval_shape(registry):
    for art in registry:
        out = jax.eval_shape(art.fn, *art.example_args)
        flat, _ = jax.tree_util.tree_flatten(out)
        assert len(flat) == len(art.output_names), art.name


@pytest.mark.parametrize("name", ["quickstart_acdc_b4_n64", "fig3_step_k2",
                                  "fig3_dense_step"])
def test_lowering_produces_parseable_hlo_text(registry, name):
    art = next(a for a in registry if a.name == name)
    text = aot.to_hlo_text(art.fn, *art.example_args)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True => root of the entry computation is a tuple
    assert "tuple(" in text or "(f32[" in text


def test_quickstart_hlo_has_expected_parameter_shapes(registry):
    art = next(a for a in registry if a.name == "quickstart_acdc_b4_n64")
    text = aot.to_hlo_text(art.fn, *art.example_args)
    assert "f32[4,64]" in text  # x
    assert "f32[64]" in text  # a / d / bias


def test_dtype_str_rejects_unknown():
    with pytest.raises(KeyError):
        aot._dtype_str(np.dtype("float64"))


def test_manifest_file_if_built():
    """If `make artifacts` has run, the on-disk manifest must be coherent."""
    mpath = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                         "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    names = [a["name"] for a in manifest["artifacts"]]
    assert len(names) == len(set(names))
    for art in manifest["artifacts"]:
        fpath = os.path.join(os.path.dirname(mpath), art["file"])
        assert os.path.exists(fpath), art["file"]
        for spec in art["inputs"] + art["outputs"]:
            assert spec["dtype"] in {"f32", "i32", "u32"}
            assert all(isinstance(s, int) and s >= 0 for s in spec["shape"])


def test_vjp_not_required_for_serving(registry):
    """Serving artifacts must lower without any grad ops (forward only)."""
    art = next(a for a in registry if a.name == "serve_cascade_b8_n256_k12")
    text = aot.to_hlo_text(art.fn, *art.example_args)
    assert "transpose" not in art.tags.get("experiment", "")


def test_perm_seed_recorded(registry):
    serve = [a for a in registry if a.tags["experiment"] == "serve"]
    assert all(a.tags["perm_seed"] == aot.PERM_SEED for a in serve)
