"""L2 correctness: custom-VJP gradients (paper §4), Fig-3 trainability,
MiniCaffeNet shapes/steps, and the §6.2 riders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed=0):
    return np.random.default_rng(seed)


def rand(r, *shape, loc=0.0, scale=1.0):
    return jnp.asarray(r.normal(loc, scale, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# §4 closed-form gradients vs autodiff of the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 32, 64])
def test_custom_vjp_matches_autodiff(n):
    r = rng(n)
    x = rand(r, 6, n)
    a = rand(r, n, loc=1.0, scale=0.1)
    d = rand(r, n, loc=1.0, scale=0.1)
    b = rand(r, n, scale=0.1)

    def loss_kernel(x, a, d, b):
        return jnp.sum(jnp.tanh(model.acdc_layer(x, a, d, b)))

    def loss_ref(x, a, d, b):
        return jnp.sum(jnp.tanh(ref.acdc(x, a, d, b)))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(x, a, d, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, a, d, b)
    for u, v in zip(gk, gr):
        np.testing.assert_allclose(u, v, atol=5e-5)


def test_custom_vjp_matches_finite_differences():
    n, r = 16, rng(3)
    x = rand(r, 2, n)
    a = rand(r, n, loc=1.0, scale=0.1)
    d = rand(r, n, loc=1.0, scale=0.1)
    b = rand(r, n, scale=0.1)

    def loss(a):
        return jnp.sum(model.acdc_layer(x, a, d, b) ** 2)

    g = jax.grad(loss)(a)
    eps = 1e-3
    for i in [0, 5, n - 1]:
        e = jnp.zeros_like(a).at[i].set(eps)
        fd = (loss(a + e) - loss(a - e)) / (2 * eps)
        np.testing.assert_allclose(g[i], fd, rtol=2e-2, atol=2e-2)


def test_cascade_gradients_flow_through_all_layers():
    n, k, r = 32, 4, rng(4)
    x = rand(r, 4, n)
    A = rand(r, k, n, loc=1.0, scale=0.1)
    D = rand(r, k, n, loc=1.0, scale=0.1)

    def loss(A, D):
        return jnp.sum(model.acdc_cascade(x, A, D) ** 2)

    gA, gD = jax.grad(loss, argnums=(0, 1))(A, D)
    assert gA.shape == (k, n) and gD.shape == (k, n)
    # every layer must receive a non-trivial gradient
    assert float(jnp.abs(gA).min(axis=1).min()) > 0.0
    assert float(jnp.abs(gD).min(axis=1).min()) > 0.0


# ---------------------------------------------------------------------------
# Initialization (paper §6: identity-plus-noise)
# ---------------------------------------------------------------------------


def test_init_diagonals_statistics():
    a, d = model.init_diagonals(jax.random.PRNGKey(0), 8, 4096, 1.0, 0.1)
    assert abs(float(a.mean()) - 1.0) < 0.01
    assert abs(float(a.std()) - 0.1) < 0.01
    assert a.shape == d.shape == (8, 4096)


def test_identity_init_cascade_is_near_identity():
    # N(1, sigma) init => cascade starts close to the identity map, which is
    # exactly why the paper's init makes deep cascades trainable.
    n, k = 32, 8
    a, d = model.init_diagonals(jax.random.PRNGKey(1), k, n, 1.0, 0.01)
    x = rand(rng(5), 4, n)
    y = model.acdc_cascade(x, a, d)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.5


def test_make_perms_deterministic_and_valid():
    p1 = model.make_perms(7, 12, 256)
    p2 = model.make_perms(7, 12, 256)
    np.testing.assert_array_equal(p1, p2)
    for row in p1:
        assert sorted(row.tolist()) == list(range(256))
    assert not np.array_equal(model.make_perms(8, 12, 256), p1)


# ---------------------------------------------------------------------------
# Figure 3 workload
# ---------------------------------------------------------------------------


def _fig3_data(r, n=32, rows=512):
    x = jnp.asarray(r.uniform(0, 1, (rows, n)).astype(np.float32))
    w = jnp.asarray(r.uniform(0, 1, (n, n)).astype(np.float32))
    y = x @ w + jnp.asarray(r.normal(0, 1e-2, (rows, n)).astype(np.float32))
    return x, y, w


def test_fig3_step_decreases_loss():
    r = rng(6)
    x, y, _ = _fig3_data(r)
    a, d = model.init_diagonals(jax.random.PRNGKey(2), 4, 32, 1.0, 0.1)
    losses = []
    lr = jnp.float32(2e-4)
    for _ in range(30):
        a, d, loss = model.fig3_step(a, d, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]


def test_fig3_dense_step_decreases_loss():
    r = rng(7)
    x, y, _ = _fig3_data(r)
    w = jnp.zeros((32, 32), jnp.float32)
    step = jax.jit(model.dense_step)
    losses = []
    for _ in range(200):
        w, loss = step(w, x, y, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.05


def test_fig3_k1_can_fit_diagonalizable_target():
    # If W_true is exactly an ACDC(a, d) operator, a K=1 cascade recovers it.
    n, r = 16, rng(8)
    a_t = rand(r, n, loc=1.0, scale=0.3)
    d_t = rand(r, n, loc=1.0, scale=0.3)
    w_true, _ = ref.acdc_dense_equivalent(a_t, d_t)
    x = jnp.asarray(r.uniform(0, 1, (256, n)).astype(np.float32))
    y = x @ w_true
    a, d = model.init_diagonals(jax.random.PRNGKey(3), 1, n, 1.0, 0.1)
    step = jax.jit(model.fig3_step)
    for i in range(1500):
        a, d, loss = step(a, d, x, y, jnp.float32(0.02))
    assert float(loss) < 5e-2, float(loss)


# ---------------------------------------------------------------------------
# MiniCaffeNet
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cnn_batch():
    r = rng(9)
    imgs = jnp.asarray(r.normal(0, 1, (16, model.IMG, model.IMG, 1)).astype(np.float32))
    labels = jnp.asarray(r.integers(0, model.N_CLASSES, 16).astype(np.int32))
    return imgs, labels


def test_cnn_acdc_logits_shape(cnn_batch):
    imgs, _ = cnn_batch
    p = model.init_cnn_acdc(jax.random.PRNGKey(0))
    perms = model.make_perms(7, model.CNN_K, model.N_FEAT)
    logits = model.cnn_acdc_logits(p, imgs, perms)
    assert logits.shape == (16, model.N_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cnn_dense_logits_shape(cnn_batch):
    imgs, _ = cnn_batch
    p = model.init_cnn_dense(jax.random.PRNGKey(0))
    logits = model.cnn_dense_logits(p, imgs)
    assert logits.shape == (16, model.N_CLASSES)


def test_cnn_param_budget_matches_table1_story():
    """The dense-vs-ACDC param ratio of the FC block must be large (the
    Table-1 effect at our scale): dense 2×(256²+256) vs ACDC 12×3×256."""
    dense_fc = 2 * (model.N_FEAT**2 + model.N_FEAT)
    acdc_fc = model.CNN_K * 3 * model.N_FEAT
    assert dense_fc == 131584
    assert acdc_fc == 9216
    assert dense_fc / acdc_fc > 14.0


def test_cnn_acdc_train_step_decreases_loss(cnn_batch):
    imgs, labels = cnn_batch
    p = model.init_cnn_acdc(jax.random.PRNGKey(0))
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    perms = model.make_perms(7, model.CNN_K, model.N_FEAT)
    first = last = None
    for i in range(25):
        p, m, loss = model.cnn_acdc_train_step(
            p, m, imgs, labels, jnp.float32(0.01), jnp.uint32(i), perms
        )
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first


def test_cnn_dense_train_step_decreases_loss(cnn_batch):
    imgs, labels = cnn_batch
    p = model.init_cnn_dense(jax.random.PRNGKey(0))
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    first = last = None
    for _ in range(25):
        p, m, loss = model.cnn_dense_train_step(
            p, m, imgs, labels, jnp.float32(0.05)
        )
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first


def test_cnn_acdc_no_weight_decay_on_diagonals(cnn_batch):
    """§6.2: A/D must not be decayed. With zero gradient flow (lr>0 but
    images=0 won't zero grads, so instead compare update to raw grad),
    check the wd term is absent on a_stack but present on cls_w."""
    imgs, labels = cnn_batch
    p = model.init_cnn_acdc(jax.random.PRNGKey(1))
    wd_mask = model._acdc_wd_mask(p)
    assert float(wd_mask.a_stack) == 0.0
    assert float(wd_mask.d_stack) == 0.0
    assert float(wd_mask.bias_stack) == 0.0
    assert float(wd_mask.cls_w) == 1.0


def test_cnn_acdc_lr_multipliers():
    p = model.init_cnn_acdc(jax.random.PRNGKey(1))
    mults = model._acdc_lr_mults(p)
    assert float(mults.a_stack) == model.LR_MULT_A == 24.0
    assert float(mults.d_stack) == model.LR_MULT_D == 12.0
    assert float(mults.conv1_w) == 1.0


def test_eval_correct_count_bounds(cnn_batch):
    imgs, labels = cnn_batch
    p = model.init_cnn_acdc(jax.random.PRNGKey(0))
    perms = model.make_perms(7, model.CNN_K, model.N_FEAT)
    loss, correct = model.cnn_acdc_eval(p, imgs, labels, perms)
    assert 0 <= int(correct) <= imgs.shape[0]
    assert float(loss) > 0.0


def test_dropout_only_active_in_training(cnn_batch):
    imgs, _ = cnn_batch
    p = model.init_cnn_acdc(jax.random.PRNGKey(0))
    perms = model.make_perms(7, model.CNN_K, model.N_FEAT)
    l1 = model.cnn_acdc_logits(p, imgs, perms, dropout_key=None)
    l2 = model.cnn_acdc_logits(p, imgs, perms, dropout_key=None)
    np.testing.assert_array_equal(l1, l2)  # eval is deterministic
    l3 = model.cnn_acdc_logits(p, imgs, perms, dropout_key=jax.random.PRNGKey(5))
    assert float(jnp.abs(l3 - l1).max()) > 0.0  # dropout changes activations


def test_serve_classifier_is_log_softmax(cnn_batch):
    r = rng(10)
    p = model.init_cnn_acdc(jax.random.PRNGKey(0))
    perms = model.make_perms(7, model.CNN_K, model.N_FEAT)
    feat = rand(r, 8, model.N_FEAT)
    out = model.serve_classifier(
        p.a_stack, p.d_stack, p.bias_stack, p.cls_w, p.cls_b, feat, perms
    )
    sums = jnp.exp(out).sum(axis=-1)
    np.testing.assert_allclose(sums, np.ones(8), atol=1e-4)
