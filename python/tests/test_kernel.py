"""L1 correctness: Pallas ACDC kernels vs the pure-jnp oracle.

The hypothesis sweeps are the core correctness signal required by the
brief: shapes (batch × n, cascade depth K) and dtypes are generated, and
every case asserts allclose against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import acdc as kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SIZES = [4, 8, 16, 32, 64, 128, 256]


def rng(seed=0):
    return np.random.default_rng(seed)


def rand_f32(r, *shape, loc=0.0, scale=1.0):
    return jnp.asarray(r.normal(loc, scale, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# DCT matrix properties (paper eq. 9)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SIZES)
def test_dct_matrix_orthogonal(n):
    c = ref.dct_matrix(n)
    np.testing.assert_allclose(c @ c.T, np.eye(n), atol=5e-6)


@pytest.mark.parametrize("n", SIZES)
def test_dct_matrix_inverse_is_transpose(n):
    c = ref.dct_matrix(n)
    np.testing.assert_allclose(c.T @ c, np.eye(n), atol=5e-6)


@pytest.mark.parametrize("n", [8, 32, 128])
def test_dct_matches_jax_scipy(n):
    import jax.scipy.fft as jsf

    x = rand_f32(rng(n), 6, n)
    np.testing.assert_allclose(
        ref.dct(x), jsf.dct(x, type=2, norm="ortho", axis=-1), atol=2e-5
    )


@pytest.mark.parametrize("n", [8, 32, 128])
def test_idct_roundtrip(n):
    x = rand_f32(rng(n + 1), 5, n)
    np.testing.assert_allclose(ref.idct(ref.dct(x)), x, atol=2e-5)


def test_dct_first_column_is_scaled_mean():
    # k=0 column of DCT-II: sqrt(2/N) * (1/sqrt(2)) * sum = sum / sqrt(N)
    n = 16
    x = rand_f32(rng(2), 3, n)
    y = ref.dct(x)
    np.testing.assert_allclose(
        y[:, 0], np.sum(np.asarray(x), axis=1) / np.sqrt(n), rtol=1e-5
    )


def test_dct_energy_preserved():
    # Orthogonal transform preserves the L2 norm (Parseval).
    x = rand_f32(rng(3), 4, 64)
    np.testing.assert_allclose(
        np.linalg.norm(ref.dct(x), axis=1),
        np.linalg.norm(np.asarray(x), axis=1),
        rtol=1e-5,
    )


# ---------------------------------------------------------------------------
# Single fused layer vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_acdc_matches_ref(n, batch):
    r = rng(n * 100 + batch)
    x = rand_f32(r, batch, n)
    a = rand_f32(r, n, loc=1.0, scale=0.1)
    d = rand_f32(r, n, loc=1.0, scale=0.1)
    b = rand_f32(r, n, scale=0.1)
    np.testing.assert_allclose(
        kernels.acdc(x, a, d, b), ref.acdc(x, a, d, b), atol=1e-4
    )


def test_acdc_no_bias_matches_ref():
    r = rng(11)
    x = rand_f32(r, 4, 32)
    a = rand_f32(r, 32, loc=1.0)
    d = rand_f32(r, 32, loc=1.0)
    np.testing.assert_allclose(
        kernels.acdc(x, a, d, None), ref.acdc(x, a, d, None), atol=1e-4
    )


def test_acdc_identity_params_is_identity():
    # a = d = 1, bias = 0  =>  x C C^T = x.
    n = 64
    x = rand_f32(rng(4), 8, n)
    ones = jnp.ones((n,), jnp.float32)
    zeros = jnp.zeros((n,), jnp.float32)
    np.testing.assert_allclose(kernels.acdc(x, ones, ones, zeros), x, atol=1e-4)


def test_acdc_is_linear_in_x():
    n, r = 32, rng(5)
    a = rand_f32(r, n, loc=1.0)
    d = rand_f32(r, n, loc=1.0)
    z = jnp.zeros((n,), jnp.float32)
    x1 = rand_f32(r, 4, n)
    x2 = rand_f32(r, 4, n)
    y = kernels.acdc(x1 + 2.0 * x2, a, d, z)
    y_lin = kernels.acdc(x1, a, d, z) + 2.0 * kernels.acdc(x2, a, d, z)
    np.testing.assert_allclose(y, y_lin, atol=1e-3)


def test_acdc_matches_dense_equivalent():
    n, r = 16, rng(6)
    a = rand_f32(r, n, loc=1.0, scale=0.2)
    d = rand_f32(r, n, loc=1.0, scale=0.2)
    b = rand_f32(r, n, scale=0.2)
    x = rand_f32(r, 5, n)
    w, bias = ref.acdc_dense_equivalent(a, d, b)
    np.testing.assert_allclose(
        kernels.acdc(x, a, d, b), x @ w + bias, atol=1e-4
    )


def test_acdc_block_b_tiling_invariance():
    # Result must not depend on the grid block size.
    n, batch = 32, 12
    r = rng(7)
    x = rand_f32(r, batch, n)
    a = rand_f32(r, n, loc=1.0)
    d = rand_f32(r, n, loc=1.0)
    z = jnp.zeros((n,), jnp.float32)
    full = kernels.acdc(x, a, d, z, block_b=12)
    for bb in [1, 2, 3, 4, 6]:
        np.testing.assert_allclose(
            kernels.acdc(x, a, d, z, block_b=bb), full, atol=1e-5
        )


# ---------------------------------------------------------------------------
# Fused cascade vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", [1, 2, 4, 8])
@pytest.mark.parametrize("relu", [False, True])
def test_cascade_matches_ref(k, relu):
    n, batch = 32, 6
    r = rng(k * 10 + relu)
    x = rand_f32(r, batch, n)
    A = rand_f32(r, k, n, loc=1.0, scale=0.1)
    D = rand_f32(r, k, n, loc=1.0, scale=0.1)
    B = rand_f32(r, k, n, scale=0.1)
    P = jnp.asarray(
        np.stack([r.permutation(n) for _ in range(k)]).astype(np.int32)
    )
    np.testing.assert_allclose(
        kernels.acdc_cascade(x, A, D, B, P, relu=relu),
        ref.acdc_cascade(x, A, D, B, P, relu=relu),
        atol=2e-4,
    )


def test_cascade_k1_equals_single_layer():
    n, r = 64, rng(9)
    x = rand_f32(r, 4, n)
    a = rand_f32(r, n, loc=1.0)
    d = rand_f32(r, n, loc=1.0)
    b = rand_f32(r, n)
    np.testing.assert_allclose(
        kernels.acdc_cascade(x, a[None], d[None], b[None]),
        kernels.acdc(x, a, d, b),
        atol=1e-4,
    )


def test_cascade_identity_perm_equals_no_perm():
    n, k, r = 32, 3, rng(10)
    x = rand_f32(r, 4, n)
    A = rand_f32(r, k, n, loc=1.0)
    D = rand_f32(r, k, n, loc=1.0)
    B = jnp.zeros((k, n), jnp.float32)
    ident = jnp.tile(jnp.arange(n, dtype=jnp.int32)[None], (k, 1))
    np.testing.assert_allclose(
        kernels.acdc_cascade(x, A, D, B, ident),
        kernels.acdc_cascade(x, A, D, B, None),
        atol=1e-5,
    )


def test_cascade_composes_dense_equivalents():
    n, k, r = 16, 3, rng(12)
    A = rand_f32(r, k, n, loc=1.0, scale=0.2)
    D = rand_f32(r, k, n, loc=1.0, scale=0.2)
    x = rand_f32(r, 5, n)
    w = ref.cascade_dense_equivalent(A, D)
    np.testing.assert_allclose(
        kernels.acdc_cascade(x, A, D), x @ w, atol=1e-3
    )


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes and dtypes (required coverage)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n_pow=st.integers(min_value=2, max_value=7),  # n = 4..128
    batch=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_acdc_shapes(n_pow, batch, seed):
    n = 2**n_pow
    r = rng(seed)
    x = rand_f32(r, batch, n)
    a = rand_f32(r, n, loc=1.0, scale=0.2)
    d = rand_f32(r, n, loc=1.0, scale=0.2)
    b = rand_f32(r, n, scale=0.2)
    np.testing.assert_allclose(
        kernels.acdc(x, a, d, b), ref.acdc(x, a, d, b), atol=2e-4
    )


@settings(max_examples=25, deadline=None)
@given(
    n_pow=st.integers(min_value=2, max_value=6),
    k=st.integers(min_value=1, max_value=6),
    batch=st.integers(min_value=1, max_value=8),
    relu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_cascade_shapes(n_pow, k, batch, relu, seed):
    n = 2**n_pow
    r = rng(seed)
    x = rand_f32(r, batch, n)
    A = rand_f32(r, k, n, loc=1.0, scale=0.15)
    D = rand_f32(r, k, n, loc=1.0, scale=0.15)
    B = rand_f32(r, k, n, scale=0.1)
    P = jnp.asarray(np.stack([r.permutation(n) for _ in range(k)]).astype(np.int32))
    np.testing.assert_allclose(
        kernels.acdc_cascade(x, A, D, B, P, relu=relu),
        ref.acdc_cascade(x, A, D, B, P, relu=relu),
        atol=5e-4,
    )


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_acdc_dtypes(dtype, seed):
    n, batch = 32, 4
    r = rng(seed)
    tol = 1e-4 if dtype == "float32" else 5e-2
    x = jnp.asarray(r.normal(0, 1, (batch, n)), dtype=dtype)
    a = jnp.asarray(r.normal(1, 0.1, (n,)), dtype=dtype)
    d = jnp.asarray(r.normal(1, 0.1, (n,)), dtype=dtype)
    b = jnp.asarray(r.normal(0, 0.1, (n,)), dtype=dtype)
    got = kernels.acdc(x, a, d, b).astype(jnp.float32)
    want = ref.acdc(
        x.astype(jnp.float32), a.astype(jnp.float32),
        d.astype(jnp.float32), b.astype(jnp.float32),
    )
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


def test_vmem_estimate_within_tpu_budget():
    # The fused cascade for the paper's largest CNN config must fit VMEM.
    assert kernels.vmem_bytes(256, k=12, block_b=128) < 16 * 2**20
    assert kernels.vmem_bytes(1024, k=2, block_b=128) < 16 * 2**20
