//! Quickstart: the whole three-layer stack in one page.
//!
//! Loads the AOT-compiled fused ACDC kernel (authored as a Pallas kernel,
//! lowered by `make artifacts`), executes it on the PJRT CPU client from
//! rust, and cross-checks the numbers against the pure-rust reference
//! implementation.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use acdc::dct::DctPlan;
use acdc::runtime::values::HostValue;
use acdc::runtime::Engine;
use acdc::sell::acdc::AcdcLayer;
use acdc::sell::LinearOp;
use acdc::tensor::Tensor;
use acdc::util::rng::Pcg32;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<(), String> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let engine = Engine::open(Path::new(&artifacts))?;
    println!("PJRT platform: {}", engine.platform());

    // The artifact: one fused ACDC layer, batch 4, N = 64.
    let art = engine.load("quickstart_acdc_b4_n64")?;
    println!(
        "loaded '{}' ({} inputs, {} outputs)",
        art.meta.name,
        art.meta.inputs.len(),
        art.meta.outputs.len()
    );

    // Random inputs with the paper's identity-plus-noise diagonals.
    let n = 64;
    let mut rng = Pcg32::seeded(7);
    let x = Tensor::from_vec(&[4, n], rng.normal_vec(4 * n, 0.0, 1.0));
    let a = rng.normal_vec(n, 1.0, 0.1);
    let d = rng.normal_vec(n, 1.0, 0.1);
    let bias = rng.normal_vec(n, 0.0, 0.1);

    // Execute the lowered Pallas kernel via PJRT.
    let out = art.call(&[
        HostValue::from_tensor(&x),
        HostValue::F32 { shape: vec![n], data: a.clone() },
        HostValue::F32 { shape: vec![n], data: d.clone() },
        HostValue::F32 { shape: vec![n], data: bias.clone() },
    ])?;
    let y_pjrt = out[0].to_tensor();

    // Same computation through the pure-rust ACDC (Makhoul DCT via FFT).
    let layer = AcdcLayer::new(a, d, bias, Arc::new(DctPlan::new(n)));
    let y_native = layer.forward_fused(&x);

    let diff = y_pjrt.max_abs_diff(&y_native);
    println!("output[0][..6] = {:?}", &y_pjrt.row(0)[..6]);
    println!("PJRT vs native reference: max |Δ| = {diff:.3e}");
    println!(
        "layer parameters: {} (vs {} for a dense {n}×{n} layer — x{:.1} fewer)",
        layer.param_count(),
        n * n,
        (n * n) as f64 / layer.param_count() as f64
    );
    assert!(diff < 1e-3, "kernel and reference disagree");
    println!("quickstart OK");
    Ok(())
}
