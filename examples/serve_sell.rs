//! Serving example: batched SELL inference through the full coordinator.
//!
//! Starts the router → dynamic batcher → PJRT worker stack over the
//! `serve_cascade_*` artifacts (a 12-layer ACDC classifier head, §6.2
//! configuration), drives an open-loop load of single-row requests from
//! several client threads, and reports latency percentiles, throughput
//! and batch occupancy.
//!
//! Run: `make artifacts && cargo run --release --example serve_sell
//!        [-- --requests 2000 --clients 8 --max-wait-us 2000]`

use acdc::config::ServeConfig;
use acdc::serve::{ServeParams, Server};
use acdc::util::bench::{fmt_ns, percentile};
use acdc::util::cli::{opt, Args};
use acdc::util::rng::Pcg32;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> Result<(), String> {
    let args = Args::parse(vec![
        opt("artifacts", "artifacts directory", Some("artifacts")),
        opt("requests", "total requests", Some("2000")),
        opt("clients", "client threads", Some("8")),
        opt("workers", "PJRT worker threads", Some("2")),
        opt("max-wait-us", "batcher deadline (µs)", Some("2000")),
    ])?;
    let requests = args.get_usize("requests")?.unwrap();
    let clients = args.get_usize("clients")?.unwrap();

    let cfg = ServeConfig {
        artifacts_dir: args.get("artifacts").unwrap().to_string(),
        buckets: vec![1, 8, 32, 128],
        max_wait_us: args.get_usize("max-wait-us")?.unwrap() as u64,
        workers: args.get_usize("workers")?.unwrap(),
        queue_cap: 8_192,
    };
    let (n, k, classes) = (256usize, 12usize, 10usize);
    println!(
        "starting server: ACDC-{k} classifier head, N={n}, buckets {:?}, {} workers",
        cfg.buckets, cfg.workers
    );
    let server = Arc::new(Server::start_pjrt(&cfg, ServeParams::random(n, k, classes, 1), n)?);

    // warmup (compile all buckets)
    for _ in 0..cfg.buckets.len() * 4 {
        let mut rng = Pcg32::seeded(99);
        server
            .infer(rng.normal_vec(n, 0.0, 1.0), Duration::from_secs(120))
            .map_err(|e| format!("warmup: {e}"))?;
    }

    println!("driving {requests} requests from {clients} client threads...");
    let t0 = Instant::now();
    let per_client = requests / clients;
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(1000 + ci as u64);
                let mut latencies = Vec::with_capacity(per_client);
                let mut batch_sizes = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let row = rng.normal_vec(n, 0.0, 1.0);
                    let t = Instant::now();
                    let rx = loop {
                        match server.submit(row.clone()) {
                            Ok(rx) => break rx,
                            Err(_) => std::thread::sleep(Duration::from_micros(100)),
                        }
                    };
                    let resp = rx.recv_timeout(Duration::from_secs(120)).expect("response");
                    resp.output.expect("inference ok");
                    latencies.push(t.elapsed().as_nanos() as f64);
                    batch_sizes.push(resp.batch_size);
                }
                (latencies, batch_sizes)
            })
        })
        .collect();

    let mut latencies = vec![];
    let mut batch_sizes = vec![];
    for h in handles {
        let (l, b) = h.join().expect("client thread");
        latencies.extend(l);
        batch_sizes.extend(b);
    }
    let wall = t0.elapsed();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let served = latencies.len();
    println!("\n== results ==");
    println!("served:      {served} requests in {:.2}s", wall.as_secs_f64());
    println!(
        "throughput:  {:.0} req/s",
        served as f64 / wall.as_secs_f64()
    );
    println!(
        "latency:     p50 {}  p90 {}  p99 {}",
        fmt_ns(percentile(&latencies, 50.0)),
        fmt_ns(percentile(&latencies, 90.0)),
        fmt_ns(percentile(&latencies, 99.0)),
    );
    let mean_batch: f64 =
        batch_sizes.iter().map(|&b| b as f64).sum::<f64>() / batch_sizes.len() as f64;
    println!("mean dispatched bucket: {mean_batch:.1}");
    println!("\n== coordinator metrics ==\n{}", server.metrics_report());
    Ok(())
}
