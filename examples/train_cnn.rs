//! E6 — end-to-end validation driver (recorded in EXPERIMENTS.md).
//!
//! Trains MiniCaffeNet with its FC block replaced by 12 stacked
//! ACDC+ReLU+permutation SELLs (§6.2 riders: bias on D, LR multipliers
//! ×24/×12, no weight decay on the diagonals, dropout before the last 5
//! SELLs, conv features scaled 0.1) on the synthetic image corpus, for a
//! few hundred steps through the AOT `cnn_acdc_train_step` artifact —
//! proving all three layers compose. The dense reference model trains
//! alongside for the Table-1-style comparison, and the final SELL
//! parameters are checkpointed.
//!
//! Run: `make artifacts && cargo run --release --example train_cnn
//!        [-- --steps 400 --train-rows 2000]`

use acdc::data::synthimg::ImageCorpus;
use acdc::runtime::Engine;
use acdc::trainer::{CnnTrainer, CnnVariant, StepDecay};
use acdc::util::cli::{opt, Args};
use acdc::util::fmt_params;
use std::path::Path;

fn main() -> Result<(), String> {
    let args = Args::parse(vec![
        opt("artifacts", "artifacts directory", Some("artifacts")),
        opt("steps", "SGD steps per variant", Some("400")),
        opt("train-rows", "training corpus size", Some("2000")),
        opt("test-rows", "test corpus size", Some("1024")),
        opt("seed", "rng seed", Some("0")),
        opt("checkpoint", "path to save the ACDC model", Some("acdc_cnn.ckpt")),
    ])?;
    let steps = args.get_usize("steps")?.unwrap();
    let train_rows = args.get_usize("train-rows")?.unwrap();
    let test_rows = args.get_usize("test-rows")?.unwrap();
    let seed = args.get_usize("seed")?.unwrap() as u64;

    let engine = Engine::open(Path::new(args.get("artifacts").unwrap()))?;
    println!("PJRT platform: {}", engine.platform());
    println!("generating synthimg corpus: {train_rows} train / {test_rows} test, 10 classes, 16×16");
    let train = ImageCorpus::generate(train_rows, 0.15, seed);
    let test = ImageCorpus::generate(test_rows, 0.15, seed + 1);

    let mut results = vec![];
    for (variant, lr, label) in [
        (CnnVariant::Dense, 0.05, "dense-FC reference"),
        (CnnVariant::Acdc, 0.02, "ACDC-12 FC (paper §6.2)"),
    ] {
        println!("\n=== training {label} for {steps} steps ===");
        let mut t = CnnTrainer::new(&engine, variant, seed + 7)?;
        println!("learnable parameters: {}", fmt_params(t.param_count() as u64));
        let before = t.eval_on_corpus(&test)?;
        println!("initial: loss {:.3}, accuracy {:.1}%", before.loss, before.accuracy * 100.0);
        let t0 = std::time::Instant::now();
        let (curve, after) = t.run(&train, &test, steps, &StepDecay::constant(lr), 20)?;
        println!("{}", curve.render(4));
        println!(
            "final:   loss {:.3}, accuracy {:.1}%  ({:.1}s, {:.1} steps/s)",
            after.loss,
            after.accuracy * 100.0,
            t0.elapsed().as_secs_f64(),
            steps as f64 / t0.elapsed().as_secs_f64()
        );
        if variant == CnnVariant::Acdc {
            let path = std::path::PathBuf::from(args.get("checkpoint").unwrap());
            t.checkpoint().save(&path)?;
            println!("checkpoint saved to {}", path.display());
        }
        results.push((label, t.param_count() as u64, after));
    }

    println!("\n=== Table-1-style summary (measured) ===");
    let (_, dense_params, dense_eval) = &results[0];
    let (_, acdc_params, acdc_eval) = &results[1];
    let dense_err = (1.0 - dense_eval.accuracy) * 100.0;
    let acdc_err = (1.0 - acdc_eval.accuracy) * 100.0;
    println!("dense FC: {} params, test err {dense_err:.1}%", fmt_params(*dense_params));
    println!(
        "ACDC-12:  {} params (x{:.1} reduction), test err {acdc_err:.1}% ({:+.1}% vs dense)",
        fmt_params(*acdc_params),
        *dense_params as f64 / *acdc_params as f64,
        acdc_err - dense_err
    );
    println!("\ntrain_cnn E2E OK — all three layers composed (Pallas kernel → jax train step → rust PJRT loop)");
    Ok(())
}
