//! Figure-3 experiment driver: approximate a dense 32×32 operator with
//! ACDC cascades of increasing depth, under the two §6 initializations.
//!
//! Run: `make artifacts && cargo run --release --example approximate_linear
//!        [-- --steps 400 --ks 1,2,4,8,16,32]`
//!
//! Exercises the AOT `fig3_step_k{K}` train-step artifacts end to end and
//! prints the paper-style panels; the same driver backs
//! `cargo bench --bench fig3_approximation`.

use acdc::data::regression::RegressionTask;
use acdc::experiments::fig3;
use acdc::runtime::Engine;
use acdc::util::cli::{opt, Args};
use std::path::Path;

fn main() -> Result<(), String> {
    let args = Args::parse(vec![
        opt("artifacts", "artifacts directory", Some("artifacts")),
        opt("steps", "SGD steps per curve", Some("400")),
        opt("ks", "comma list of cascade depths", Some("1,2,4,8,16,32")),
        opt("rows", "regression rows (paper: 10000)", Some("10000")),
        opt("seed", "rng seed", Some("0")),
    ])?;
    let steps = args.get_usize("steps")?.unwrap();
    let ks = args.get_usize_list("ks")?.unwrap();
    let rows = args.get_usize("rows")?.unwrap();
    let seed = args.get_usize("seed")?.unwrap() as u64;

    let engine = Engine::open(Path::new(args.get("artifacts").unwrap()))?;
    println!("generating eq. (15) regression: X {rows}×32, noise N(0, 1e-4)");
    let task = RegressionTask::generate(rows, 32, 1e-4, seed);

    println!("training {} curves × {steps} steps through PJRT artifacts...", 2 * ks.len() + 1);
    let cells = fig3::run(&engine, &task, &ks, steps, seed)?;
    print!("{}", fig3::render(&cells, &task));

    match fig3::check_paper_shape(&cells) {
        Ok(()) => println!("paper-shape checks: OK (identity trains, near-zero init fails at depth)"),
        Err(e) => println!("paper-shape checks: FAILED — {e}"),
    }
    Ok(())
}
